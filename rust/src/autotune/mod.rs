//! E11 — hybrid operating-point autotuner.
//!
//! The paper's headline is a tension: centralized wins communication
//! (~790×), decentralized wins computation (~1400×), so the conclusion
//! calls for a semi-decentralized hybrid.  Everything below `autotune`
//! can *evaluate* one operating point — the analytic `netmodel`
//! (Eqs. 1–7 + E8), the packet-level `netsim` fabric, the serving
//! coordinators — but nothing *searches* the space.  This module is the
//! design-space explorer: given a deployment scale, a materialized graph
//! sample, and a [`TuneGrid`] over
//! {setting} × {cluster size} × {head capacity} × {partitioner},
//! it scores every point, returns the Pareto frontier over
//! (latency, energy, per-device power) and the latency argmin
//! [`OperatingPoint`], which the coordinators consume through their
//! `from_operating_point` constructors.
//!
//! **Determinism contract (DESIGN.md §9):** enumeration order is fixed
//! (settings in grid order; cluster size, then head capacity, then
//! partitioner), every score is a pure function of
//! (model, graph, deployment scale, point), the parallel driver writes
//! results by slot index, ties on the argmin and frontier break toward
//! the earliest point — so `explore` is bit-identical across thread
//! counts and runs, and equals exhaustive brute-force enumeration
//! (asserted in `rust/tests/autotune_cross_validation.rs`).
//!
//! DESIGN.md: §9 (operating-point autotuner).

mod pareto;

pub use pareto::{dominates, pareto_frontier};

use std::collections::BTreeMap;

use crate::error::{Error, Result};
use crate::graph::{self, Csr};
use crate::netmodel::{NetModel, Setting, Topology};
use crate::netsim::{simulate_fabric, NetSimConfig, Scenario};
use crate::par;
use crate::units::{Energy, Power, Time};

/// Deployment setting of one grid point (the semi-decentralized hybrid
/// joins the paper's two pure settings).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SettingKind {
    Centralized,
    Semi,
    Decentralized,
}

impl SettingKind {
    pub fn name(&self) -> &'static str {
        match self {
            SettingKind::Centralized => "centralized",
            SettingKind::Semi => "semi",
            SettingKind::Decentralized => "decentralized",
        }
    }
}

/// Which cluster partitioner produces the clustering a point is scored at.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Partitioner {
    FixedSize,
    Locality,
}

impl Partitioner {
    pub fn name(&self) -> &'static str {
        match self {
            Partitioner::FixedSize => "fixed_size",
            Partitioner::Locality => "locality",
        }
    }

    /// Partition `graph` into clusters of at most `cluster_size`.
    pub fn partition(&self, graph: &Csr, cluster_size: usize) -> Result<graph::Clustering> {
        match self {
            Partitioner::FixedSize => graph::fixed_size(graph.num_nodes(), cluster_size),
            Partitioner::Locality => graph::locality(graph, cluster_size),
        }
    }
}

/// One candidate deployment configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OperatingPoint {
    pub setting: SettingKind,
    /// Requested cluster size cₛ (0 for the canonical centralized point,
    /// whose score has no cluster structure).
    pub cluster_size: usize,
    /// Cluster-head capacity multiple (1.0 unless semi).
    pub head_capacity: f64,
    pub partitioner: Partitioner,
}

impl OperatingPoint {
    /// The canonical centralized point (cluster knobs are meaningless).
    pub fn centralized() -> OperatingPoint {
        OperatingPoint {
            setting: SettingKind::Centralized,
            cluster_size: 0,
            head_capacity: 1.0,
            partitioner: Partitioner::FixedSize,
        }
    }

    pub fn decentralized(cluster_size: usize, partitioner: Partitioner) -> OperatingPoint {
        OperatingPoint {
            setting: SettingKind::Decentralized,
            cluster_size,
            head_capacity: 1.0,
            partitioner,
        }
    }

    pub fn semi(
        cluster_size: usize,
        head_capacity: f64,
        partitioner: Partitioner,
    ) -> OperatingPoint {
        OperatingPoint { setting: SettingKind::Semi, cluster_size, head_capacity, partitioner }
    }

    /// Human-readable label for tables and JSON.
    pub fn label(&self) -> String {
        match self.setting {
            SettingKind::Centralized => "centralized".into(),
            SettingKind::Decentralized => {
                format!("decentralized cs={} {}", self.cluster_size, self.partitioner.name())
            }
            SettingKind::Semi => format!(
                "semi cs={} h={} {}",
                self.cluster_size,
                self.head_capacity,
                self.partitioner.name()
            ),
        }
    }
}

/// Clustering-derived facts a score depends on (pure function of the
/// sample graph, the partitioner and the requested cluster size).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterFacts {
    /// Largest cluster — the straggler that closes a round.
    pub max_size: usize,
    /// Cluster count on the sample graph.
    pub clusters: usize,
    /// Fraction of edges kept inside clusters (drives the boundary terms
    /// of the clustered Eq. 4 / E8 variants).
    pub intra_fraction: f64,
}

impl ClusterFacts {
    /// Facts for the centralized point: no cluster structure.
    fn none() -> ClusterFacts {
        ClusterFacts { max_size: 0, clusters: 0, intra_fraction: 1.0 }
    }
}

/// The three objectives every point is scored on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Score {
    /// Total round latency (compute + communicate), the argmin objective.
    pub latency: Time,
    /// Energy of one full-graph inference round at deployment scale.
    pub energy: Energy,
    /// Power of the hottest single device (the leader / a head / a node).
    pub per_device_power: Power,
}

impl Score {
    /// Fault-adjusted latency objective (the E14 hook): a Poisson
    /// arrival lands inside a crash window with probability
    /// `1 − availability` and then waits the window's mean residual —
    /// `mttr / 2` for the fixed-duration outages the fault sweep
    /// charges.  `availability = 1` returns the raw latency unchanged,
    /// so fault-free tuning is bit-identical to the seed scoring.
    pub fn effective_latency(&self, availability: f64, mttr: Time) -> Time {
        let a = availability.clamp(0.0, 1.0);
        if a == 1.0 {
            return self.latency;
        }
        self.latency + mttr * (0.5 * (1.0 - a))
    }
}

/// Packet-level cross-check attached by the netsim refinement pass.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimCheck {
    /// Scale the fabric was simulated at (`min(N, netsim_nodes_cap)`).
    pub nodes: usize,
    /// Simulated round completion at that scale.
    pub simulated: Time,
    /// Analytic latency at the same scale (the congestion-free baseline;
    /// the gap between the two is the contention signal).
    pub analytic: Time,
}

/// One scored grid point.
#[derive(Debug, Clone, PartialEq)]
pub struct EvaluatedPoint {
    pub point: OperatingPoint,
    pub facts: ClusterFacts,
    pub score: Score,
    pub simulated: Option<SimCheck>,
}

/// Which engine produces the latency objective.
#[derive(Debug, Clone)]
pub enum Backend {
    /// Closed-form Eqs. 1–7 + the clustered E11 variants.
    Analytic,
    /// Packet-level `netsim` round completion (energy / per-device power
    /// stay analytic; the fabric sees the clustering only through its
    /// straggler cluster size).  Deployments larger than
    /// [`TunerConfig::netsim_nodes_cap`] are simulated at the cap.
    Netsim(NetSimConfig),
}

/// The enumeration grid.
#[derive(Debug, Clone)]
pub struct TuneGrid {
    pub settings: Vec<SettingKind>,
    pub cluster_sizes: Vec<usize>,
    pub head_capacities: Vec<f64>,
    pub partitioners: Vec<Partitioner>,
}

impl TuneGrid {
    /// All three settings × both partitioners over the given cluster
    /// sizes and head capacities.
    pub fn full(cluster_sizes: &[usize], head_capacities: &[f64]) -> TuneGrid {
        TuneGrid {
            settings: vec![
                SettingKind::Centralized,
                SettingKind::Semi,
                SettingKind::Decentralized,
            ],
            cluster_sizes: cluster_sizes.to_vec(),
            head_capacities: head_capacities.to_vec(),
            partitioners: vec![Partitioner::FixedSize, Partitioner::Locality],
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.settings.is_empty() {
            return Err(Error::Config("autotune grid has no settings".into()));
        }
        let clustered = self
            .settings
            .iter()
            .any(|s| matches!(s, SettingKind::Semi | SettingKind::Decentralized));
        if clustered {
            if self.cluster_sizes.is_empty() || self.cluster_sizes.contains(&0) {
                return Err(Error::Config(
                    "autotune grid needs cluster sizes > 0 for clustered settings".into(),
                ));
            }
            if self.partitioners.is_empty() {
                return Err(Error::Config("autotune grid has no partitioners".into()));
            }
        }
        if self.settings.contains(&SettingKind::Semi) {
            if self.head_capacities.is_empty() {
                return Err(Error::Config("autotune grid has no head capacities".into()));
            }
            if self.head_capacities.iter().any(|h| !h.is_finite() || *h < 1.0) {
                return Err(Error::Config("head capacities must be finite and >= 1".into()));
            }
        }
        Ok(())
    }

    /// Canonical enumeration: settings in grid order; within a setting,
    /// cluster size → head capacity → partitioner; centralized collapses
    /// to its single canonical point.  This order is the tie-break order
    /// of the argmin and the frontier.
    pub fn points(&self) -> Vec<OperatingPoint> {
        let mut pts = Vec::new();
        for &setting in &self.settings {
            match setting {
                SettingKind::Centralized => pts.push(OperatingPoint::centralized()),
                SettingKind::Semi => {
                    for &cs in &self.cluster_sizes {
                        for &h in &self.head_capacities {
                            for &p in &self.partitioners {
                                pts.push(OperatingPoint::semi(cs, h, p));
                            }
                        }
                    }
                }
                SettingKind::Decentralized => {
                    for &cs in &self.cluster_sizes {
                        for &p in &self.partitioners {
                            pts.push(OperatingPoint::decentralized(cs, p));
                        }
                    }
                }
            }
        }
        pts
    }
}

/// Exploration knobs.
#[derive(Debug, Clone)]
pub struct TunerConfig {
    pub backend: Backend,
    /// With the analytic backend: re-score this many of the best points
    /// with the packet fabric as a congestion cross-check (0 = off).
    pub netsim_refine: usize,
    /// Fabric config of the refinement pass.
    pub netsim: NetSimConfig,
    /// Largest deployment the packet fabric simulates (bigger scales are
    /// capped; the [`SimCheck`] records the scale actually simulated).
    pub netsim_nodes_cap: usize,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            backend: Backend::Analytic,
            netsim_refine: 0,
            netsim: NetSimConfig::default(),
            netsim_nodes_cap: 2_000,
        }
    }
}

/// Result of one exploration.
#[derive(Debug, Clone, PartialEq)]
pub struct TuneOutcome {
    /// Every grid point, in canonical enumeration order.
    pub evaluated: Vec<EvaluatedPoint>,
    /// Indices of the Pareto frontier over
    /// (latency, energy, per-device power), in enumeration order.
    pub pareto: Vec<usize>,
    /// Index of the latency argmin (earliest point wins ties).
    pub best: usize,
}

impl TuneOutcome {
    pub fn best_point(&self) -> &EvaluatedPoint {
        &self.evaluated[self.best]
    }

    pub fn pareto_points(&self) -> impl Iterator<Item = &EvaluatedPoint> {
        self.pareto.iter().map(|&i| &self.evaluated[i])
    }
}

/// The design-space explorer for one deployment.
pub struct Autotuner<'a> {
    model: &'a NetModel,
    /// Materialized graph sample the partitioners run on (its clustering
    /// statistics — straggler size, intra-edge fraction — stand in for
    /// the full graph's, DESIGN.md §2 substitution).
    graph: &'a Csr,
    /// Deployment scale N (may exceed the sample).
    nodes: usize,
    grid: TuneGrid,
    cfg: TunerConfig,
}

impl<'a> Autotuner<'a> {
    pub fn new(
        model: &'a NetModel,
        graph: &'a Csr,
        nodes: usize,
        grid: TuneGrid,
        cfg: TunerConfig,
    ) -> Result<Autotuner<'a>> {
        grid.validate()?;
        if nodes < 2 {
            return Err(Error::Config("autotune needs a deployment of >= 2 nodes".into()));
        }
        if graph.num_nodes() == 0 {
            return Err(Error::Config("autotune needs a non-empty sample graph".into()));
        }
        Ok(Autotuner { model, graph, nodes, grid, cfg })
    }

    pub fn grid(&self) -> &TuneGrid {
        &self.grid
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Clustering facts for one (partitioner, cluster size) cell — a pure,
    /// deterministic function of the sample graph.
    pub fn cluster_facts(
        &self,
        partitioner: Partitioner,
        cluster_size: usize,
    ) -> Result<ClusterFacts> {
        let c = partitioner.partition(self.graph, cluster_size)?;
        Ok(ClusterFacts {
            max_size: c.max_size(),
            clusters: c.num_clusters(),
            intra_fraction: c.intra_edge_fraction(self.graph),
        })
    }

    /// Score one operating point with the configured backend — the single
    /// scoring path `explore` and the brute-force cross-validation share.
    pub fn score(&self, point: &OperatingPoint) -> Result<EvaluatedPoint> {
        let facts = self.facts_for(point)?;
        let score = self.score_at(point, &facts, self.nodes)?;
        Ok(EvaluatedPoint { point: *point, facts, score, simulated: None })
    }

    /// Enumerate, score and rank the whole grid over all available cores.
    pub fn explore(&self) -> Result<TuneOutcome> {
        self.explore_with_threads(par::available_threads())
    }

    /// [`Self::explore`] with an explicit worker count (1 = sequential);
    /// the outcome is identical at every thread count.
    pub fn explore_with_threads(&self, threads: usize) -> Result<TuneOutcome> {
        let points = self.grid.points();
        if points.is_empty() {
            return Err(Error::Config("autotune grid enumerates no points".into()));
        }
        // Clustering facts per grid cell, computed once up front so the
        // parallel scoring pass is read-only.
        let mut facts: BTreeMap<(Partitioner, usize), ClusterFacts> = BTreeMap::new();
        for p in &points {
            if p.setting != SettingKind::Centralized {
                if let std::collections::btree_map::Entry::Vacant(e) =
                    facts.entry((p.partitioner, p.cluster_size))
                {
                    e.insert(self.cluster_facts(p.partitioner, p.cluster_size)?);
                }
            }
        }
        let mut evaluated = par::par_try_map(&points, threads, |p| -> Result<EvaluatedPoint> {
            let f = match p.setting {
                SettingKind::Centralized => ClusterFacts::none(),
                _ => facts[&(p.partitioner, p.cluster_size)],
            };
            let score = self.score_at(p, &f, self.nodes)?;
            Ok(EvaluatedPoint { point: *p, facts: f, score, simulated: None })
        })?;

        // Optional packet-level cross-check of the best analytic points.
        if matches!(self.cfg.backend, Backend::Analytic) && self.cfg.netsim_refine > 0 {
            let mut order: Vec<usize> = (0..evaluated.len()).collect();
            order.sort_by(|&a, &b| {
                evaluated[a]
                    .score
                    .latency
                    .partial_cmp(&evaluated[b].score.latency)
                    .expect("latencies are finite")
                    .then(a.cmp(&b))
            });
            for &i in order.iter().take(self.cfg.netsim_refine) {
                let (p, f) = (evaluated[i].point, evaluated[i].facts);
                let sim_nodes = self.nodes.min(self.cfg.netsim_nodes_cap).max(2);
                let simulated = self.netsim_latency(&p, &f, sim_nodes, &self.cfg.netsim)?;
                let analytic = self.score_at(&p, &f, sim_nodes)?.latency;
                evaluated[i].simulated =
                    Some(SimCheck { nodes: sim_nodes, simulated, analytic });
            }
        }

        let best = evaluated
            .iter()
            .enumerate()
            .min_by(|(ai, a), (bi, b)| {
                a.score
                    .latency
                    .partial_cmp(&b.score.latency)
                    .expect("latencies are finite")
                    .then(ai.cmp(bi))
            })
            .map(|(i, _)| i)
            .expect("grid is non-empty");
        let scores: Vec<Score> = evaluated.iter().map(|e| e.score).collect();
        let pareto = pareto_frontier(&scores);
        Ok(TuneOutcome { evaluated, pareto, best })
    }

    fn facts_for(&self, point: &OperatingPoint) -> Result<ClusterFacts> {
        match point.setting {
            SettingKind::Centralized => Ok(ClusterFacts::none()),
            _ => self.cluster_facts(point.partitioner, point.cluster_size),
        }
    }

    /// Score `point` for a deployment of `nodes` devices (DESIGN.md §9).
    fn score_at(
        &self,
        point: &OperatingPoint,
        facts: &ClusterFacts,
        nodes: usize,
    ) -> Result<Score> {
        if point.setting != SettingKind::Centralized && point.cluster_size == 0 {
            return Err(Error::Config("clustered settings need cluster size > 0".into()));
        }
        if point.setting == SettingKind::Semi
            && (!point.head_capacity.is_finite() || point.head_capacity < 1.0)
        {
            return Err(Error::Config("head capacity must be finite and >= 1".into()));
        }
        let m = self.model;
        let n = nodes as f64;
        let cs = facts.max_size.max(1);
        let topo = Topology { nodes, cluster_size: cs };
        let latency = match &self.cfg.backend {
            Backend::Analytic => match point.setting {
                SettingKind::Centralized => m.latency(Setting::Centralized, topo).total(),
                SettingKind::Decentralized => {
                    m.compute_latency(Setting::Decentralized, topo)
                        + m.communicate_latency_clustered(topo, facts.intra_fraction)
                }
                SettingKind::Semi => m
                    .semi_latency_clustered(topo, point.head_capacity, facts.intra_fraction)
                    .total(),
            },
            Backend::Netsim(cfg) => {
                let sim_nodes = nodes.min(self.cfg.netsim_nodes_cap).max(2);
                self.netsim_latency(point, facts, sim_nodes, cfg)?
            }
        };
        // Energy of one full-graph round and the hottest device's power
        // are analytic in both backends (the fabric models latency only).
        let (energy, per_device_power) = match point.setting {
            SettingKind::Centralized => {
                let (ec, em) = m.inference_energy(Setting::Centralized, topo);
                let p = m.compute_power(Setting::Centralized)
                    + m.communicate_power(Setting::Centralized);
                (ec + em, p)
            }
            SettingKind::Decentralized => {
                let comm = m.communicate_latency_clustered(topo, facts.intra_fraction);
                let e = m.breakdown().total_energy() * n
                    + m.communicate_power(Setting::Decentralized) * comm * n;
                let p = m.compute_power(Setting::Decentralized)
                    + m.communicate_power(Setting::Decentralized);
                (e, p)
            }
            SettingKind::Semi => {
                let transfer = m.inter_link().transfer(m.message_bytes());
                let beta = 2.0 - facts.intra_fraction.clamp(0.0, 1.0);
                let heads = nodes.div_ceil(cs) as f64;
                // member up+down per device, boundary exchange per head.
                let e = m.breakdown().total_energy() * n
                    + m.inter_link().power() * (transfer * (2.0 * n + 2.0 * beta * heads));
                // The head is the hottest device: h× a member's cores plus
                // its two-way V2X radio.
                let p = m.compute_power(Setting::Decentralized) * point.head_capacity
                    + m.inter_link().power() * 2.0;
                (e, p)
            }
        };
        Ok(Score { latency, energy, per_device_power })
    }

    /// Packet-level round completion for `point` at `sim_nodes` devices.
    /// The fabric sees the clustering only through its straggler size —
    /// the same `max_size` the analytic forms use, so a [`SimCheck`]
    /// compares identical topologies; the intra-edge fraction is an
    /// analytic-only refinement.
    fn netsim_latency(
        &self,
        point: &OperatingPoint,
        facts: &ClusterFacts,
        sim_nodes: usize,
        cfg: &NetSimConfig,
    ) -> Result<Time> {
        let cs = facts.max_size.max(1);
        let (scenario, topo) = match point.setting {
            SettingKind::Centralized => {
                (Scenario::CentralizedStar, Topology { nodes: sim_nodes, cluster_size: 1 })
            }
            SettingKind::Decentralized => (
                Scenario::DecentralizedMesh,
                Topology { nodes: sim_nodes, cluster_size: cs },
            ),
            SettingKind::Semi => (
                Scenario::SemiOverlay { head_capacity: point.head_capacity },
                Topology { nodes: sim_nodes, cluster_size: cs },
            ),
        };
        Ok(simulate_fabric(self.model, scenario, topo, cfg)?.completion)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cores::GnnWorkload;
    use crate::graph::generate;
    use crate::testing::assert_close;

    fn model() -> NetModel {
        NetModel::paper(&GnnWorkload::taxi()).unwrap()
    }

    #[test]
    fn grid_enumeration_is_canonical_and_counts_match() {
        let g = TuneGrid::full(&[5, 10], &[4.0, 8.0]);
        let pts = g.points();
        // 1 centralized + 2·2·2 semi + 2·2 decentralized.
        assert_eq!(pts.len(), 1 + 8 + 4);
        assert_eq!(pts[0], OperatingPoint::centralized());
        assert_eq!(pts[1], OperatingPoint::semi(5, 4.0, Partitioner::FixedSize));
        assert_eq!(pts[2], OperatingPoint::semi(5, 4.0, Partitioner::Locality));
        assert_eq!(pts[3], OperatingPoint::semi(5, 8.0, Partitioner::FixedSize));
        assert_eq!(*pts.last().unwrap(), OperatingPoint::decentralized(10, Partitioner::Locality));
    }

    /// E14 scoring hook: full availability is bit-identical to the raw
    /// latency; partial availability charges the mean residual of the
    /// outage window, monotonically in both knobs.
    #[test]
    fn effective_latency_charges_expected_outage_residual() {
        let s = Score {
            latency: Time::ms(4.0),
            energy: Energy::mj(1.0),
            per_device_power: Power::w(1.0),
        };
        assert_eq!(
            s.effective_latency(1.0, Time::s(10.0)).as_s().to_bits(),
            s.latency.as_s().to_bits()
        );
        // 2% unavailable, 100 ms windows: + 0.02 · 50 ms = 1 ms.
        assert_close(s.effective_latency(0.98, Time::ms(100.0)).as_ms(), 5.0, 1e-12);
        let worse = s.effective_latency(0.9, Time::ms(100.0));
        let better = s.effective_latency(0.98, Time::ms(100.0));
        assert!(worse > better && better > s.latency);
        // Out-of-range availabilities clamp instead of extrapolating.
        assert_eq!(
            s.effective_latency(2.0, Time::s(1.0)).as_s().to_bits(),
            s.latency.as_s().to_bits()
        );
    }

    #[test]
    fn grid_validation_rejects_degenerate_knobs() {
        let mut g = TuneGrid::full(&[5], &[4.0]);
        g.settings.clear();
        assert!(g.validate().is_err());
        let g = TuneGrid::full(&[0], &[4.0]);
        assert!(g.validate().is_err());
        let g = TuneGrid::full(&[5], &[0.5]);
        assert!(g.validate().is_err());
        let mut g = TuneGrid::full(&[5], &[]);
        assert!(g.validate().is_err());
        // ... but a centralized-only grid needs none of the cluster knobs.
        g.settings = vec![SettingKind::Centralized];
        g.cluster_sizes.clear();
        g.partitioners.clear();
        assert!(g.validate().is_ok());
        assert_eq!(g.points(), vec![OperatingPoint::centralized()]);
    }

    #[test]
    fn cluster_facts_match_direct_partitioning() {
        let m = model();
        let g = generate::ring(24).unwrap();
        let t = Autotuner::new(&m, &g, 24, TuneGrid::full(&[6], &[4.0]), TunerConfig::default())
            .unwrap();
        let f = t.cluster_facts(Partitioner::FixedSize, 6).unwrap();
        let c = crate::graph::fixed_size(24, 6).unwrap();
        assert_eq!(f.max_size, c.max_size());
        assert_eq!(f.clusters, c.num_clusters());
        assert_close(f.intra_fraction, c.intra_edge_fraction(&g), 1e-12);
        // Ring arithmetic: 4 arcs of 6 keep 2·5 of their 12 edges… per arc.
        assert_close(f.intra_fraction, (24.0 - 4.0) / 24.0, 1e-12);
    }

    #[test]
    fn locality_scores_no_worse_than_blocking_on_structured_graphs() {
        let m = model();
        let g = generate::grid(8, 8).unwrap();
        let t = Autotuner::new(&m, &g, 64, TuneGrid::full(&[8], &[8.0]), TunerConfig::default())
            .unwrap();
        for (a, b) in [
            (
                OperatingPoint::decentralized(8, Partitioner::Locality),
                OperatingPoint::decentralized(8, Partitioner::FixedSize),
            ),
            (
                OperatingPoint::semi(8, 8.0, Partitioner::Locality),
                OperatingPoint::semi(8, 8.0, Partitioner::FixedSize),
            ),
        ] {
            let la = t.score(&a).unwrap().score.latency;
            let lb = t.score(&b).unwrap().score.latency;
            assert!(la <= lb, "{} {la} > {} {lb}", a.label(), b.label());
        }
    }

    #[test]
    fn explore_is_identical_across_thread_counts() {
        let m = model();
        let g = generate::grid(6, 8).unwrap();
        let t = Autotuner::new(
            &m,
            &g,
            5_000,
            TuneGrid::full(&[4, 8, 12], &[4.0, 10.0]),
            TunerConfig { netsim_refine: 2, ..Default::default() },
        )
        .unwrap();
        let seq = t.explore_with_threads(1).unwrap();
        let par4 = t.explore_with_threads(4).unwrap();
        let auto = t.explore().unwrap();
        assert_eq!(seq, par4);
        assert_eq!(seq, auto);
        assert_eq!(seq.evaluated.len(), 1 + 12 + 6);
        // The refinement annotated exactly two points.
        assert_eq!(seq.evaluated.iter().filter(|e| e.simulated.is_some()).count(), 2);
        // The argmin sits on the frontier (it is latency-minimal).
        assert!(seq.pareto.contains(&seq.best));
    }

    #[test]
    fn uncongested_netsim_backend_agrees_with_analytic_on_aligned_clusters() {
        // Two 5-cliques: fixed_size(·, 5) aligns exactly with the
        // components, so the intra fraction is 1 and the analytic
        // clustered forms coincide with the paper equations the fabric
        // reproduces.
        let mut edges = Vec::new();
        for base in [0usize, 5] {
            for i in 0..5 {
                for j in 0..5 {
                    if i != j {
                        edges.push((base + i, base + j));
                    }
                }
            }
        }
        let g = Csr::from_edges(10, &edges).unwrap();
        let m = model();
        let grid = TuneGrid {
            settings: vec![
                SettingKind::Centralized,
                SettingKind::Semi,
                SettingKind::Decentralized,
            ],
            cluster_sizes: vec![5],
            head_capacities: vec![5.0],
            partitioners: vec![Partitioner::FixedSize],
        };
        let analytic =
            Autotuner::new(&m, &g, 40, grid.clone(), TunerConfig::default()).unwrap();
        let simulated = Autotuner::new(
            &m,
            &g,
            40,
            grid,
            TunerConfig {
                backend: Backend::Netsim(NetSimConfig::default()),
                netsim_nodes_cap: 64,
                ..Default::default()
            },
        )
        .unwrap();
        for p in [
            OperatingPoint::centralized(),
            OperatingPoint::decentralized(5, Partitioner::FixedSize),
            OperatingPoint::semi(5, 5.0, Partitioner::FixedSize),
        ] {
            let a = analytic.score(&p).unwrap();
            let s = simulated.score(&p).unwrap();
            assert_eq!(a.facts, s.facts);
            assert!((a.facts.intra_fraction - 1.0).abs() < 1e-12);
            assert_close(s.score.latency.as_s(), a.score.latency.as_s(), 1e-6);
            // Non-latency objectives are shared verbatim.
            assert_eq!(s.score.energy, a.score.energy);
            assert_eq!(s.score.per_device_power, a.score.per_device_power);
        }
    }

    #[test]
    fn scores_reject_malformed_points() {
        let m = model();
        let g = generate::ring(12).unwrap();
        let t = Autotuner::new(&m, &g, 12, TuneGrid::full(&[4], &[4.0]), TunerConfig::default())
            .unwrap();
        assert!(t.score(&OperatingPoint::decentralized(0, Partitioner::FixedSize)).is_err());
        assert!(t.score(&OperatingPoint::semi(4, 0.25, Partitioner::FixedSize)).is_err());
        assert!(t.score(&OperatingPoint::semi(4, f64::INFINITY, Partitioner::FixedSize)).is_err());
        assert!(t.score(&OperatingPoint::semi(4, f64::NAN, Partitioner::FixedSize)).is_err());
        // Constructor guards.
        assert!(Autotuner::new(&m, &g, 1, TuneGrid::full(&[4], &[4.0]), TunerConfig::default())
            .is_err());
        let empty = Csr::from_edges(0, &[]).unwrap();
        assert!(Autotuner::new(&m, &empty, 10, TuneGrid::full(&[4], &[4.0]), TunerConfig::default())
            .is_err());
    }

    #[test]
    fn large_scale_argmin_is_the_hybrid() {
        // At LiveJournal scale with 1-byte messages the tuned hybrid beats
        // both pure settings (the paper-conclusion demonstration E11
        // asserts dataset-by-dataset in experiments.rs).
        let stats = crate::graph::datasets::livejournal();
        let m = NetModel::fig8(&stats).unwrap();
        let g = stats.materialize(600, 42).unwrap();
        let t = Autotuner::new(
            &m,
            &g,
            stats.nodes,
            TuneGrid::full(&[8, 16], &[10.0, 25.0]),
            TunerConfig::default(),
        )
        .unwrap();
        let out = t.explore_with_threads(1).unwrap();
        let best = out.best_point();
        assert_eq!(best.point.setting, SettingKind::Semi, "best: {}", best.point.label());
        let cent = t.score(&OperatingPoint::centralized()).unwrap().score.latency;
        let dec = t
            .score(&OperatingPoint::decentralized(8, Partitioner::FixedSize))
            .unwrap()
            .score
            .latency;
        assert!(best.score.latency < cent && best.score.latency < dec);
    }
}
