//! Unit-safe physical quantities for the hardware model.
//!
//! Everything the circuit / architecture / network layers exchange is one of
//! [`Time`], [`Energy`], [`Power`] or [`Area`].  Newtypes over `f64` keep the
//! arithmetic honest (`Energy / Time = Power`, etc.) and `Display` picks a
//! human scale (`14.27 µs`, `780.1 mW`) so reports read like the paper's
//! tables.
//!
//! DESIGN.md: §2 (circuit level; every hardware figure is unit-typed).

use std::fmt;
use std::iter::Sum;
use std::ops::{Add, AddAssign, Div, Mul, Neg, Sub};

macro_rules! quantity {
    ($name:ident, $base_doc:expr) => {
        #[doc = $base_doc]
        #[derive(Debug, Clone, Copy, PartialEq, PartialOrd, Default)]
        pub struct $name(f64);

        impl $name {
            pub const ZERO: $name = $name(0.0);

            /// Raw value in the base unit.
            pub fn value(self) -> f64 {
                self.0
            }

            pub fn is_finite(self) -> bool {
                self.0.is_finite()
            }

            pub fn max(self, other: Self) -> Self {
                $name(self.0.max(other.0))
            }

            pub fn min(self, other: Self) -> Self {
                $name(self.0.min(other.0))
            }

            pub fn abs(self) -> Self {
                $name(self.0.abs())
            }
        }

        impl Add for $name {
            type Output = $name;
            fn add(self, rhs: $name) -> $name {
                $name(self.0 + rhs.0)
            }
        }

        impl AddAssign for $name {
            fn add_assign(&mut self, rhs: $name) {
                self.0 += rhs.0;
            }
        }

        impl Sub for $name {
            type Output = $name;
            fn sub(self, rhs: $name) -> $name {
                $name(self.0 - rhs.0)
            }
        }

        impl Neg for $name {
            type Output = $name;
            fn neg(self) -> $name {
                $name(-self.0)
            }
        }

        impl Mul<f64> for $name {
            type Output = $name;
            fn mul(self, rhs: f64) -> $name {
                $name(self.0 * rhs)
            }
        }

        impl Mul<$name> for f64 {
            type Output = $name;
            fn mul(self, rhs: $name) -> $name {
                $name(self * rhs.0)
            }
        }

        impl Div<f64> for $name {
            type Output = $name;
            fn div(self, rhs: f64) -> $name {
                $name(self.0 / rhs)
            }
        }

        impl Div<$name> for $name {
            type Output = f64;
            fn div(self, rhs: $name) -> f64 {
                self.0 / rhs.0
            }
        }

        impl Sum for $name {
            fn sum<I: Iterator<Item = $name>>(iter: I) -> $name {
                $name(iter.map(|q| q.0).sum())
            }
        }
    };
}

quantity!(Time, "Duration; base unit: seconds.");
quantity!(Energy, "Energy; base unit: joules.");
quantity!(Power, "Power; base unit: watts.");
quantity!(Area, "Silicon area; base unit: square meters.");

impl Time {
    pub fn s(v: f64) -> Time {
        Time(v)
    }
    pub fn ms(v: f64) -> Time {
        Time(v * 1e-3)
    }
    pub fn us(v: f64) -> Time {
        Time(v * 1e-6)
    }
    pub fn ns(v: f64) -> Time {
        Time(v * 1e-9)
    }
    pub fn ps(v: f64) -> Time {
        Time(v * 1e-12)
    }
    pub fn as_s(self) -> f64 {
        self.0
    }
    pub fn as_ms(self) -> f64 {
        self.0 * 1e3
    }
    pub fn as_us(self) -> f64 {
        self.0 * 1e6
    }
    pub fn as_ns(self) -> f64 {
        self.0 * 1e9
    }
}

impl Energy {
    pub fn j(v: f64) -> Energy {
        Energy(v)
    }
    pub fn mj(v: f64) -> Energy {
        Energy(v * 1e-3)
    }
    pub fn uj(v: f64) -> Energy {
        Energy(v * 1e-6)
    }
    pub fn nj(v: f64) -> Energy {
        Energy(v * 1e-9)
    }
    pub fn pj(v: f64) -> Energy {
        Energy(v * 1e-12)
    }
    pub fn fj(v: f64) -> Energy {
        Energy(v * 1e-15)
    }
    pub fn as_j(self) -> f64 {
        self.0
    }
    pub fn as_pj(self) -> f64 {
        self.0 * 1e12
    }
}

impl Power {
    pub fn w(v: f64) -> Power {
        Power(v)
    }
    pub fn mw(v: f64) -> Power {
        Power(v * 1e-3)
    }
    pub fn uw(v: f64) -> Power {
        Power(v * 1e-6)
    }
    pub fn nw(v: f64) -> Power {
        Power(v * 1e-9)
    }
    pub fn as_w(self) -> f64 {
        self.0
    }
    pub fn as_mw(self) -> f64 {
        self.0 * 1e3
    }
}

impl Area {
    pub fn mm2(v: f64) -> Area {
        Area(v * 1e-6)
    }
    pub fn um2(v: f64) -> Area {
        Area(v * 1e-12)
    }
    pub fn as_mm2(self) -> f64 {
        self.0 * 1e6
    }
    pub fn as_um2(self) -> f64 {
        self.0 * 1e12
    }
}

// Cross-quantity physics.
impl Div<Time> for Energy {
    type Output = Power;
    /// `P = E / t`.
    fn div(self, rhs: Time) -> Power {
        Power(self.0 / rhs.0)
    }
}

impl Mul<Time> for Power {
    type Output = Energy;
    /// `E = P · t`.
    fn mul(self, rhs: Time) -> Energy {
        Energy(self.0 * rhs.0)
    }
}

impl Mul<Power> for Time {
    type Output = Energy;
    fn mul(self, rhs: Power) -> Energy {
        Energy(self.0 * rhs.0)
    }
}

impl Div<Power> for Energy {
    type Output = Time;
    /// `t = E / P`.
    fn div(self, rhs: Power) -> Time {
        Time(self.0 / rhs.0)
    }
}

fn scaled(v: f64, scales: &[(f64, &'static str)]) -> (f64, &'static str) {
    let a = v.abs();
    for &(s, name) in scales {
        if a >= s {
            return (v / s, name);
        }
    }
    let &(s, name) = scales.last().unwrap();
    (v / s, name)
}

impl fmt::Display for Time {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 == 0.0 {
            return write!(f, "0 s");
        }
        let (v, u) = scaled(
            self.0,
            &[(1.0, "s"), (1e-3, "ms"), (1e-6, "µs"), (1e-9, "ns"), (1e-12, "ps")],
        );
        write!(f, "{:.2} {}", v, u)
    }
}

impl fmt::Display for Power {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 == 0.0 {
            return write!(f, "0 W");
        }
        let (v, u) = scaled(self.0, &[(1.0, "W"), (1e-3, "mW"), (1e-6, "µW"), (1e-9, "nW")]);
        write!(f, "{:.2} {}", v, u)
    }
}

impl fmt::Display for Energy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.0 == 0.0 {
            return write!(f, "0 J");
        }
        let (v, u) = scaled(
            self.0,
            &[
                (1.0, "J"),
                (1e-3, "mJ"),
                (1e-6, "µJ"),
                (1e-9, "nJ"),
                (1e-12, "pJ"),
                (1e-15, "fJ"),
                (1e-18, "aJ"),
            ],
        );
        write!(f, "{:.2} {}", v, u)
    }
}

impl fmt::Display for Area {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{:.4} mm²", self.as_mm2())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors_roundtrip() {
        assert!((Time::ns(7.68).as_ns() - 7.68).abs() < 1e-12);
        assert!((Time::us(14.27).as_us() - 14.27).abs() < 1e-12);
        assert!((Power::mw(41.6).as_mw() - 41.6).abs() < 1e-12);
        assert!((Energy::pj(3.0).as_pj() - 3.0).abs() < 1e-12);
        assert!((Area::um2(25.0).as_um2() - 25.0).abs() < 1e-9);
    }

    #[test]
    fn physics_identities() {
        let e = Energy::pj(100.0);
        let t = Time::ns(50.0);
        let p = e / t; // 100 pJ / 50 ns = 2 mW
        assert!((p.as_mw() - 2.0).abs() < 1e-9);
        let back = p * t;
        assert!((back.as_pj() - 100.0).abs() < 1e-9);
        let t2 = e / p;
        assert!((t2.as_ns() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn arithmetic() {
        let a = Time::ns(1.0) + Time::ns(2.0);
        assert!((a.as_ns() - 3.0).abs() < 1e-12);
        let r = Time::us(10.0) / Time::us(2.0);
        assert!((r - 5.0).abs() < 1e-12);
        let s: Time = [Time::ns(1.0), Time::ns(2.0), Time::ns(3.0)].into_iter().sum();
        assert!((s.as_ns() - 6.0).abs() < 1e-12);
        assert_eq!(Time::ns(5.0).max(Time::ns(3.0)), Time::ns(5.0));
    }

    #[test]
    fn display_picks_readable_scales() {
        assert_eq!(Time::us(14.27).to_string(), "14.27 µs");
        assert_eq!(Time::ns(7.68).to_string(), "7.68 ns");
        assert_eq!(Time::ms(3.3).to_string(), "3.30 ms");
        assert_eq!(Power::mw(780.1).to_string(), "780.10 mW");
        assert_eq!(Time::ZERO.to_string(), "0 s");
    }

    #[test]
    fn display_sub_resolution_values() {
        // Below the smallest scale we still format (in the smallest unit).
        let tiny = Energy::j(1e-20);
        assert!(tiny.to_string().contains("aJ"));
    }
}
