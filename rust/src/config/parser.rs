//! TOML-subset parser (offline `toml` crate substitute).
//!
//! Supports what our config files use: `[section]` / `[section.sub]`
//! headers, `key = value` pairs with string / integer / float / boolean /
//! homogeneous-array values, `#` comments and blank lines.  Keys are
//! flattened to `section.sub.key` paths in a [`RawConfig`] map.
//!
//! DESIGN.md: §2 (circuit level; presets load through this parser).

use std::collections::BTreeMap;

use crate::error::{Error, Result};

/// A parsed scalar or array value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Flat `section.key -> Value` map with typed accessors.
#[derive(Debug, Clone, Default)]
pub struct RawConfig {
    values: BTreeMap<String, Value>,
}

impl RawConfig {
    pub fn get(&self, path: &str) -> Option<&Value> {
        self.values.get(path)
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }

    pub fn f64(&self, path: &str) -> Result<f64> {
        self.get(path)
            .and_then(Value::as_f64)
            .ok_or_else(|| Error::Config(format!("missing or non-numeric key `{path}`")))
    }

    pub fn f64_or(&self, path: &str, default: f64) -> f64 {
        self.get(path).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize(&self, path: &str) -> Result<usize> {
        self.get(path)
            .and_then(Value::as_usize)
            .ok_or_else(|| Error::Config(format!("missing or non-integer key `{path}`")))
    }

    pub fn usize_or(&self, path: &str, default: usize) -> usize {
        self.get(path).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, path: &str, default: &'a str) -> &'a str {
        self.get(path).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn bool_or(&self, path: &str, default: bool) -> bool {
        self.get(path).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn set(&mut self, path: impl Into<String>, v: Value) {
        self.values.insert(path.into(), v);
    }
}

fn parse_scalar(text: &str, line_no: usize) -> Result<Value> {
    let t = text.trim();
    let err = |m: &str| Error::Config(format!("line {line_no}: {m}: `{t}`"));
    if t.is_empty() {
        return Err(err("empty value"));
    }
    if let Some(inner) = t.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| err("unterminated string"))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if t == "true" {
        return Ok(Value::Bool(true));
    }
    if t == "false" {
        return Ok(Value::Bool(false));
    }
    if t.starts_with('[') {
        let inner = t
            .strip_prefix('[')
            .and_then(|s| s.strip_suffix(']'))
            .ok_or_else(|| err("unterminated array"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in inner.split(',') {
                items.push(parse_scalar(part, line_no)?);
            }
        }
        return Ok(Value::Array(items));
    }
    // Numbers: underscores allowed as digit separators, scientific notation ok.
    let clean = t.replace('_', "");
    if let Ok(i) = clean.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(err("unrecognized value"))
}

/// Strip a `#` comment that is not inside a string literal.
fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

/// Parse TOML-subset text into a flat config map.
pub fn parse(text: &str) -> Result<RawConfig> {
    let mut cfg = RawConfig::default();
    let mut section = String::new();
    for (idx, raw_line) in text.lines().enumerate() {
        let line_no = idx + 1;
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(head) = line.strip_prefix('[') {
            let name = head
                .strip_suffix(']')
                .ok_or_else(|| Error::Config(format!("line {line_no}: unterminated section")))?
                .trim();
            if name.is_empty() {
                return Err(Error::Config(format!("line {line_no}: empty section name")));
            }
            section = name.to_string();
            continue;
        }
        let eq = line
            .find('=')
            .ok_or_else(|| Error::Config(format!("line {line_no}: expected `key = value`")))?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(Error::Config(format!("line {line_no}: empty key")));
        }
        let value = parse_scalar(&line[eq + 1..], line_no)?;
        let path = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        cfg.set(path, value);
    }
    Ok(cfg)
}

/// Parse a config file from disk.
pub fn parse_file(path: &std::path::Path) -> Result<RawConfig> {
    let text = std::fs::read_to_string(path)?;
    parse(&text)
}

#[cfg(test)]
mod tests {
    use super::*;

    const DOC: &str = r#"
# paper presets
name = "ima-gnn"        # inline comment
[crossbar]
rows = 512
cols = 512
read_pulse_ns = 10.5
levels = [1, 2, 4]
double_buffer = true
[comm.v2x]
packet_bytes = 300
latency_ms = 1.1
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = parse(DOC).unwrap();
        assert_eq!(c.get("name").unwrap().as_str(), Some("ima-gnn"));
        assert_eq!(c.usize("crossbar.rows").unwrap(), 512);
        assert!((c.f64("crossbar.read_pulse_ns").unwrap() - 10.5).abs() < 1e-12);
        assert!(c.bool_or("crossbar.double_buffer", false));
        assert!((c.f64("comm.v2x.latency_ms").unwrap() - 1.1).abs() < 1e-12);
        match c.get("crossbar.levels").unwrap() {
            Value::Array(v) => assert_eq!(v.len(), 3),
            other => panic!("expected array, got {other:?}"),
        }
    }

    #[test]
    fn defaults_apply() {
        let c = parse("").unwrap();
        assert_eq!(c.usize_or("nope", 7), 7);
        assert!((c.f64_or("nope", 2.5) - 2.5).abs() < 1e-12);
        assert_eq!(c.str_or("nope", "x"), "x");
    }

    #[test]
    fn underscore_digit_separators() {
        let c = parse("n = 4_847_571").unwrap();
        assert_eq!(c.usize("n").unwrap(), 4_847_571);
    }

    #[test]
    fn scientific_notation() {
        let c = parse("x = 1.5e-9").unwrap();
        assert!((c.f64("x").unwrap() - 1.5e-9).abs() < 1e-21);
    }

    #[test]
    fn hash_inside_string_is_not_comment() {
        let c = parse("s = \"a#b\"").unwrap();
        assert_eq!(c.get("s").unwrap().as_str(), Some("a#b"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse("[unterminated").is_err());
        assert!(parse("keyonly").is_err());
        assert!(parse("k = ").is_err());
        assert!(parse("k = \"open").is_err());
        assert!(parse("k = [1, 2").is_err());
        assert!(parse("[]").is_err());
    }

    #[test]
    fn missing_key_errors_name_the_key() {
        let c = parse("").unwrap();
        let e = c.f64("agg.rows").unwrap_err();
        assert!(e.to_string().contains("agg.rows"));
    }
}
