//! Typed configuration for every layer of the stack, plus the paper presets.
//!
//! The hierarchy mirrors the paper's Fig. 5 bottom-up framework:
//! [`DeviceParams`] (circuit level) -> [`CrossbarGeometry`] / [`CoreConfig`]
//! (architecture level) -> [`AcceleratorConfig`] + [`CommConfig`]
//! (application level).  `presets::centralized()` / `presets::decentralized()`
//! reproduce §4.1's core sizings: 2K×(512×32), 1K×(512×512), 256×(128×128)
//! vs 512×32, 512×512, 128×128.
//!
//! DESIGN.md: §2 (circuit level).

pub mod parser;

pub use parser::{parse, parse_file, RawConfig, Value};

use crate::error::{Error, Result};
use crate::units::{Energy, Power, Time};

/// Circuit-level constants: Ag-Si RRAM device (paper ref [21]) and 45 nm
/// CMOS peripherals (paper refs [22]-[25]).  These stand in for the paper's
/// HSPICE + NVSIM-CAM + MNSIM outputs (DESIGN.md §2) and are calibrated so
/// that the composed per-core figures reproduce Table 1.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceParams {
    /// RRAM low-resistance state (Ag-Si, ~1 kΩ class).
    pub r_on_ohm: f64,
    /// RRAM high-resistance state.
    pub r_off_ohm: f64,
    /// Read voltage applied on the bit-lines.
    pub v_read: f64,
    /// Array settle/evaluate time for one analog MVM pass.
    pub array_settle: Time,
    /// Match/compare line settle time of the CAM arrays (much shorter than
    /// an MVM evaluate: the match line only dis/charges against one row).
    pub cam_settle: Time,
    /// Energy of one active cell during one evaluate pass.
    pub cell_read_energy: Energy,
    /// Leakage of one cell (1T1R) including its access transistor.
    pub cell_leakage: Power,
    /// DAC: drive one input bit-plane onto the bit-lines.
    pub dac_latency: Time,
    pub dac_energy: Energy,
    /// ADC: one conversion of one source-line sample.
    pub adc_latency: Time,
    pub adc_energy: Energy,
    /// Sample & hold of all source-lines (per pass).
    pub sh_latency: Time,
    pub sh_energy: Energy,
    /// Shift & add recombination (per pass).
    pub shift_add_latency: Time,
    pub shift_add_energy: Energy,
    /// CAM match-line sense amplifier (per search).
    pub mlsa_latency: Time,
    pub mlsa_energy: Energy,
    /// Search-data / wordline driver (per CAM op).
    pub driver_latency: Time,
    pub driver_energy: Energy,
    /// Activation unit shared by feature-extraction crossbars (per pass).
    pub activation_latency: Time,
    pub activation_energy: Energy,
    /// Buffer array / controller overhead power per active core.
    pub buffer_power: Power,
}

impl DeviceParams {
    /// 45 nm / Ag-Si defaults, calibrated so the composed core figures
    /// reproduce Table 1 (see `cores::tests` and EXPERIMENTS.md):
    /// t₁ = 2·(driver + cam_settle + MLSA) = 7.68 ns,
    /// t₂ = 144·(DAC + settle + S&H + 64·ADC + S&A) = 14.27 µs,
    /// t₃ = 16·(DAC + settle + S&H + 4·ADC + S&A) + act = 0.37 µs.
    pub fn default_45nm() -> DeviceParams {
        DeviceParams {
            r_on_ohm: 1.0e3,
            r_off_ohm: 1.0e6,
            v_read: 0.2,
            array_settle: Time::ns(13.0),
            cam_settle: Time::ns(1.92),
            cell_read_energy: Energy::fj(15.327),
            cell_leakage: Power::nw(0.64),
            dac_latency: Time::ns(1.0),
            dac_energy: Energy::pj(1.0),
            adc_latency: Time::ns(1.28),
            adc_energy: Energy::pj(1.6),
            sh_latency: Time::ns(1.0),
            sh_energy: Energy::pj(0.5),
            shift_add_latency: Time::ns(2.18),
            shift_add_energy: Energy::pj(0.5),
            mlsa_latency: Time::ns(1.14),
            mlsa_energy: Energy::pj(0.4064),
            driver_latency: Time::ns(0.78),
            driver_energy: Energy::pj(0.4),
            activation_latency: Time::ns(13.2),
            activation_energy: Energy::pj(222.7),
            buffer_power: Power::uw(50.0),
        }
    }

    pub fn validate(&self) -> Result<()> {
        let positive = [
            ("r_on_ohm", self.r_on_ohm),
            ("r_off_ohm", self.r_off_ohm),
            ("v_read", self.v_read),
            ("array_settle", self.array_settle.value()),
            ("adc_latency", self.adc_latency.value()),
        ];
        for (name, v) in positive {
            if !(v > 0.0) {
                return Err(Error::Config(format!("device param `{name}` must be > 0, got {v}")));
            }
        }
        if self.r_off_ohm <= self.r_on_ohm {
            return Err(Error::Config("r_off must exceed r_on".into()));
        }
        Ok(())
    }
}

/// Geometry of one resistive crossbar array.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CrossbarGeometry {
    /// Word-lines (rows); inputs stream across rows.
    pub rows: usize,
    /// Source-lines (columns); outputs accumulate per column.
    pub cols: usize,
    /// Bits per RRAM cell (conductance levels = 2^bits).
    pub cell_bits: u32,
    /// Input (DAC) resolution in bits; one bit-plane per evaluate pass.
    pub input_bits: u32,
    /// ADC converters per crossbar (columns share ADCs round-robin).
    pub adcs: usize,
    /// ADC resolution in bits (clipping boundary of the analog sum).
    pub adc_bits: u32,
}

impl CrossbarGeometry {
    pub fn new(rows: usize, cols: usize) -> CrossbarGeometry {
        CrossbarGeometry { rows, cols, cell_bits: 4, input_bits: 8, adcs: 8, adc_bits: 13 }
    }

    pub fn cells(&self) -> usize {
        self.rows * self.cols
    }

    /// Sequential ADC conversions needed to read out all columns once.
    pub fn adc_rounds(&self) -> usize {
        self.cols.div_ceil(self.adcs.max(1))
    }

    pub fn validate(&self) -> Result<()> {
        if self.rows == 0 || self.cols == 0 {
            return Err(Error::Config(format!(
                "crossbar geometry must be non-empty, got {}x{}",
                self.rows, self.cols
            )));
        }
        if self.cell_bits == 0 || self.input_bits == 0 || self.adc_bits == 0 {
            return Err(Error::Config("bit widths must be >= 1".into()));
        }
        if self.adcs == 0 {
            return Err(Error::Config("need at least one ADC per crossbar".into()));
        }
        Ok(())
    }
}

/// One IMA-GNN core: a bank of identical crossbars.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoreConfig {
    pub geometry: CrossbarGeometry,
    /// Number of crossbars in the bank (the paper's 2K / 1K / 256 vs 1).
    pub crossbars: usize,
}

impl CoreConfig {
    pub fn new(crossbars: usize, rows: usize, cols: usize) -> CoreConfig {
        CoreConfig { geometry: CrossbarGeometry::new(rows, cols), crossbars }
    }

    pub fn validate(&self) -> Result<()> {
        self.geometry.validate()?;
        if self.crossbars == 0 {
            return Err(Error::Config("core needs at least one crossbar".into()));
        }
        Ok(())
    }
}

/// Full accelerator: the three cores of paper Fig. 2(a).
#[derive(Debug, Clone, PartialEq)]
pub struct AcceleratorConfig {
    pub device: DeviceParams,
    pub traversal: CoreConfig,
    pub aggregation: CoreConfig,
    pub feature: CoreConfig,
    /// Double buffering of feature/graph data (paper §2.3) — overlaps the
    /// traversal stage with aggregation-core programming.
    pub double_buffering: bool,
}

impl AcceleratorConfig {
    pub fn validate(&self) -> Result<()> {
        self.device.validate()?;
        self.traversal.validate()?;
        self.aggregation.validate()?;
        self.feature.validate()?;
        Ok(())
    }

    /// Relative capacity vs a reference accelerator: the paper's M₁/M₂/M₃.
    pub fn capacity_ratios(&self, per_node: &AcceleratorConfig) -> (f64, f64, f64) {
        let ratio = |a: &CoreConfig, b: &CoreConfig| {
            (a.crossbars * a.geometry.cells()) as f64 / (b.crossbars * b.geometry.cells()) as f64
        };
        (
            ratio(&self.traversal, &per_node.traversal),
            ratio(&self.aggregation, &per_node.aggregation),
            ratio(&self.feature, &per_node.feature),
        )
    }
}

/// Communication-link parameters (paper §3 + §4.2).
#[derive(Debug, Clone, PartialEq)]
pub struct CommConfig {
    /// Inter-network (centralized) link, paper ref [19]: measured V2X
    /// latency for a 300-byte packet at 300 m range.
    pub v2x_packet_bytes: usize,
    pub v2x_packet_latency: Time,
    /// Inter-cluster (decentralized) ad-hoc link, paper ref [20]:
    /// IEEE 802.11n ch. 9, 2.452 GHz, -31 dBm, 20 MHz.
    /// Connection establishment between two adjacent nodes (tₑ): ad-hoc
    /// association + route discovery.
    pub adhoc_setup: Time,
    /// Per-hop store-and-forward fixed delay (relay processing).
    pub adhoc_hop_latency: Time,
    /// Effective ad-hoc goodput (bytes/second) at the configured TX power.
    pub adhoc_goodput_bps: f64,
    /// Energy per transmitted bit on the ad-hoc link (Eq. 7's E_perBit).
    pub adhoc_energy_per_bit: Energy,
    /// Transmit power of the inter-network radio (for p(L_n)).
    pub v2x_tx_power: Power,
}

impl CommConfig {
    /// Paper-calibrated defaults.  With cₛ = 10 and an 864-byte message the
    /// decentralized round trip is (tₑ + 10·t(L_c))·2 = 406 ms (Table 1) and
    /// the four-dataset communication ratio averages ≈ 790× (Fig. 8); tₑ
    /// covers ad-hoc association + route discovery, the per-hop delay the
    /// store-and-forward relay of paper ref [20].
    pub fn paper() -> CommConfig {
        CommConfig {
            v2x_packet_bytes: 300,
            v2x_packet_latency: Time::ms(1.1),
            adhoc_setup: Time::ms(86.36),
            adhoc_hop_latency: Time::ms(10.8),
            adhoc_goodput_bps: 1.0e6,
            adhoc_energy_per_bit: Energy::nj(50.0),
            v2x_tx_power: Power::mw(200.0),
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.v2x_packet_bytes == 0 {
            return Err(Error::Config("v2x packet size must be > 0".into()));
        }
        if !(self.adhoc_goodput_bps > 0.0) {
            return Err(Error::Config("ad-hoc goodput must be > 0".into()));
        }
        Ok(())
    }
}

/// Paper presets (§4.1).
pub mod presets {
    use super::*;

    /// Centralized accelerator: 2K×(512×32) traversal, 1K×(512×512)
    /// aggregation, 256×(128×128) feature extraction.  (2K/1K are decimal —
    /// the paper's reported centralized latencies equal t·(N−1)/M only with
    /// M₁ = 2000 and M₂ = 1000; see EXPERIMENTS.md E1.)
    pub fn centralized() -> AcceleratorConfig {
        AcceleratorConfig {
            device: DeviceParams::default_45nm(),
            traversal: CoreConfig::new(2000, 512, 32),
            aggregation: CoreConfig::new(1000, 512, 512),
            feature: CoreConfig {
                geometry: CrossbarGeometry { adcs: 32, ..CrossbarGeometry::new(128, 128) },
                crossbars: 256,
            },
            double_buffering: true,
        }
    }

    /// Decentralized per-node accelerator: one crossbar per core.
    pub fn decentralized() -> AcceleratorConfig {
        AcceleratorConfig {
            device: DeviceParams::default_45nm(),
            traversal: CoreConfig::new(1, 512, 32),
            aggregation: CoreConfig::new(1, 512, 512),
            feature: CoreConfig {
                geometry: CrossbarGeometry { adcs: 32, ..CrossbarGeometry::new(128, 128) },
                crossbars: 1,
            },
            double_buffering: true,
        }
    }

    /// Load an accelerator config from a TOML-subset file, falling back to
    /// `base` for missing keys.
    pub fn from_raw(raw: &RawConfig, base: AcceleratorConfig) -> Result<AcceleratorConfig> {
        let mut cfg = base;
        let core = |raw: &RawConfig, name: &str, base: CoreConfig| -> Result<CoreConfig> {
            let mut c = base;
            c.crossbars = raw.usize_or(&format!("{name}.crossbars"), c.crossbars);
            c.geometry.rows = raw.usize_or(&format!("{name}.rows"), c.geometry.rows);
            c.geometry.cols = raw.usize_or(&format!("{name}.cols"), c.geometry.cols);
            c.geometry.adcs = raw.usize_or(&format!("{name}.adcs"), c.geometry.adcs);
            c.geometry.input_bits =
                raw.usize_or(&format!("{name}.input_bits"), c.geometry.input_bits as usize) as u32;
            c.geometry.cell_bits =
                raw.usize_or(&format!("{name}.cell_bits"), c.geometry.cell_bits as usize) as u32;
            Ok(c)
        };
        cfg.traversal = core(raw, "traversal", cfg.traversal)?;
        cfg.aggregation = core(raw, "aggregation", cfg.aggregation)?;
        cfg.feature = core(raw, "feature", cfg.feature)?;
        if let Some(v) = raw.get("accelerator.double_buffering").and_then(Value::as_bool) {
            cfg.double_buffering = v;
        }
        cfg.device.array_settle =
            Time::ns(raw.f64_or("device.array_settle_ns", cfg.device.array_settle.as_ns()));
        cfg.device.adc_latency =
            Time::ns(raw.f64_or("device.adc_latency_ns", cfg.device.adc_latency.as_ns()));
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_match_paper_sizing() {
        let c = presets::centralized();
        assert_eq!(c.traversal.crossbars, 2000);
        assert_eq!((c.traversal.geometry.rows, c.traversal.geometry.cols), (512, 32));
        assert_eq!(c.aggregation.crossbars, 1000);
        assert_eq!((c.aggregation.geometry.rows, c.aggregation.geometry.cols), (512, 512));
        assert_eq!(c.feature.crossbars, 256);
        assert_eq!((c.feature.geometry.rows, c.feature.geometry.cols), (128, 128));
        c.validate().unwrap();

        let d = presets::decentralized();
        assert_eq!(d.traversal.crossbars, 1);
        d.validate().unwrap();
    }

    #[test]
    fn capacity_ratios_are_the_paper_m_factors() {
        let (m1, m2, m3) = presets::centralized().capacity_ratios(&presets::decentralized());
        assert_eq!(m1, 2000.0);
        assert_eq!(m2, 1000.0);
        assert_eq!(m3, 256.0);
    }

    #[test]
    fn geometry_helpers() {
        let g = CrossbarGeometry::new(512, 512);
        assert_eq!(g.cells(), 512 * 512);
        assert_eq!(g.adc_rounds(), 64); // 512 cols / 8 ADCs
        let g2 = CrossbarGeometry { adcs: 100, ..CrossbarGeometry::new(16, 30) };
        assert_eq!(g2.adc_rounds(), 1);
    }

    #[test]
    fn validation_rejects_nonsense() {
        let mut c = presets::decentralized();
        c.traversal.crossbars = 0;
        assert!(c.validate().is_err());

        let mut c = presets::decentralized();
        c.aggregation.geometry.rows = 0;
        assert!(c.validate().is_err());

        let mut d = DeviceParams::default_45nm();
        d.r_off_ohm = d.r_on_ohm / 2.0;
        assert!(d.validate().is_err());

        let mut comm = CommConfig::paper();
        comm.adhoc_goodput_bps = 0.0;
        assert!(comm.validate().is_err());
    }

    #[test]
    fn from_raw_overrides_and_falls_back() {
        let raw = parse("[aggregation]\ncrossbars = 4\nrows = 256\n").unwrap();
        let cfg = presets::from_raw(&raw, presets::decentralized()).unwrap();
        assert_eq!(cfg.aggregation.crossbars, 4);
        assert_eq!(cfg.aggregation.geometry.rows, 256);
        // untouched values fall back to the base preset
        assert_eq!(cfg.aggregation.geometry.cols, 512);
        assert_eq!(cfg.traversal.crossbars, 1);
    }

    #[test]
    fn comm_paper_defaults() {
        let c = CommConfig::paper();
        assert_eq!(c.v2x_packet_bytes, 300);
        assert!((c.v2x_packet_latency.as_ms() - 1.1).abs() < 1e-9);
        c.validate().unwrap();
    }
}
