//! Experiment harness: one function per paper table / figure.
//!
//! Shared by the `ima-gnn` CLI and the `rust/benches/*` targets so every
//! artifact is regenerated from exactly one code path (DESIGN.md §4).

use crate::cores::GnnWorkload;
use crate::error::Result;
use crate::graph::datasets;
use crate::netmodel::{NetModel, Setting, Topology};
use crate::report::{speedup, BarSeries, Table};
use crate::units::Time;

/// Paper values of Table 1 (for side-by-side reporting).
pub mod paper {
    /// (row, centralized latency s, centralized power W, decentralized
    /// latency s, decentralized power W); `None` power = "-" in the paper.
    pub const TABLE1: &[(&str, f64, Option<f64>, f64, Option<f64>)] = &[
        ("Traversal", 38.43e-9, Some(10.8e-3), 7.68e-9, Some(0.21e-3)),
        ("Aggregation", 142.77e-6, Some(780.1e-3), 14.27e-6, Some(41.6e-3)),
        ("Feature extraction", 14.53e-6, Some(32.21e-3), 0.37e-6, Some(3.68e-3)),
        ("Computation (Net)", 157.34e-6, Some(823.11e-3), 14.6e-6, Some(45.49e-3)),
        ("Communication", 3.30e-3, None, 406e-3, None),
    ];
    pub const FIG8_COMPUTE_SPEEDUP: f64 = 1400.0;
    pub const FIG8_COMM_SPEEDUP: f64 = 790.0;
}

/// E1 — Table 1 rows, modeled vs paper.
pub struct Table1 {
    pub model: NetModel,
    pub topo: Topology,
}

impl Table1 {
    pub fn new() -> Result<Table1> {
        Ok(Table1 { model: NetModel::paper(&GnnWorkload::taxi())?, topo: Topology::taxi() })
    }

    /// Modeled values in paper row order:
    /// (label, cent latency, cent power W, dec latency, dec power W).
    pub fn rows(&self) -> Vec<(String, Time, Option<f64>, Time, Option<f64>)> {
        let m = &self.model;
        let c = m.per_core_latency(Setting::Centralized, self.topo);
        let d = m.per_core_latency(Setting::Decentralized, self.topo);
        let (cp1, cp2, cp3) = m.per_core_power(Setting::Centralized);
        let (dp1, dp2, dp3) = m.per_core_power(Setting::Decentralized);
        vec![
            ("Traversal".into(), c.traversal, Some(cp1.as_w()), d.traversal, Some(dp1.as_w())),
            ("Aggregation".into(), c.aggregation, Some(cp2.as_w()), d.aggregation, Some(dp2.as_w())),
            (
                "Feature extraction".into(),
                c.feature,
                Some(cp3.as_w()),
                d.feature,
                Some(dp3.as_w()),
            ),
            (
                "Computation (Net)".into(),
                c.total(),
                Some(m.compute_power(Setting::Centralized).as_w()),
                d.total(),
                Some(m.compute_power(Setting::Decentralized).as_w()),
            ),
            (
                "Communication".into(),
                m.communicate_latency(Setting::Centralized, self.topo),
                None,
                m.communicate_latency(Setting::Decentralized, self.topo),
                None,
            ),
        ]
    }

    /// Render modeled-vs-paper table.
    pub fn render(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Table 1 — IMA-GNN latency/power (taxi case study, N={}, cs={})",
                self.topo.nodes, self.topo.cluster_size
            ),
            &[
                "Figure of merit",
                "Cent latency",
                "(paper)",
                "Cent power",
                "(paper)",
                "Dec latency",
                "(paper)",
                "Dec power",
                "(paper)",
            ],
        );
        let fmt_p = |w: Option<f64>| {
            w.map(|v| format!("{:.2} mW", v * 1e3)).unwrap_or_else(|| "-".into())
        };
        for (row, paper_row) in self.rows().iter().zip(paper::TABLE1) {
            t.row(&[
                row.0.clone(),
                row.1.to_string(),
                Time::s(paper_row.1).to_string(),
                fmt_p(row.2),
                fmt_p(paper_row.2),
                row.3.to_string(),
                Time::s(paper_row.3).to_string(),
                fmt_p(row.4),
                fmt_p(paper_row.4),
            ]);
        }
        t
    }

    /// Worst relative error vs the paper across all numeric cells.
    pub fn max_relative_error(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for (row, p) in self.rows().iter().zip(paper::TABLE1) {
            worst = worst.max((row.1.as_s() - p.1).abs() / p.1);
            worst = worst.max((row.3.as_s() - p.3).abs() / p.3);
            if let (Some(got), Some(want)) = (row.2, p.2) {
                worst = worst.max((got - want).abs() / want);
            }
            if let (Some(got), Some(want)) = (row.4, p.4) {
                worst = worst.max((got - want).abs() / want);
            }
        }
        worst
    }
}

/// E3 — Fig. 8 series + headline averages.
pub struct Fig8 {
    /// (dataset, centralized (compute, comm), decentralized (compute, comm)).
    pub series: Vec<(String, (Time, Time), (Time, Time))>,
}

impl Fig8 {
    pub fn new() -> Result<Fig8> {
        let mut series = Vec::new();
        for d in datasets::all() {
            let m = NetModel::fig8(&d)?;
            let topo = Topology { nodes: d.nodes, cluster_size: d.avg_cs };
            let c = m.latency(Setting::Centralized, topo);
            let dec = m.latency(Setting::Decentralized, topo);
            series.push((
                d.name.to_string(),
                (c.compute, c.communicate),
                (dec.compute, dec.communicate),
            ));
        }
        Ok(Fig8 { series })
    }

    /// Average decentralized-compute speedup (paper: ~1400×).
    pub fn avg_compute_speedup(&self) -> f64 {
        self.series.iter().map(|(_, c, d)| c.0 / d.0).sum::<f64>() / self.series.len() as f64
    }

    /// Average centralized-communication speedup (paper: ~790×).
    pub fn avg_comm_speedup(&self) -> f64 {
        self.series.iter().map(|(_, c, d)| d.1 / c.1).sum::<f64>() / self.series.len() as f64
    }

    pub fn render(&self) -> BarSeries {
        let mut b = BarSeries::new(
            "Fig. 8 — computation + communication latency per dataset and setting",
            "s",
        );
        for (name, c, d) in &self.series {
            b.bar(format!("{name} / centralized"), &[("comp", c.0.as_s()), ("comm", c.1.as_s())]);
            b.bar(format!("{name} / decentralized"), &[("comp", d.0.as_s()), ("comm", d.1.as_s())]);
        }
        b
    }

    pub fn summary(&self) -> String {
        format!(
            "decentralized computes {} faster (paper: ~1400x); centralized communicates {} faster (paper: ~790x)",
            speedup(self.avg_compute_speedup()),
            speedup(self.avg_comm_speedup()),
        )
    }
}

/// E2 — Table 2 statistics (published + materialized check).
pub fn table2(materialize_cap: usize) -> Result<Table> {
    let mut t = Table::new(
        "Table 2 — key statistics of the graph datasets",
        &["Dataset", "Nodes", "Edges", "Feature length", "Avg Cs", "materialized avg degree"],
    );
    for d in datasets::all() {
        let g = d.materialize(materialize_cap, 42)?;
        t.row(&[
            d.name.to_string(),
            d.nodes.to_string(),
            d.edges.to_string(),
            d.feature_len.to_string(),
            d.avg_cs.to_string(),
            format!("{:.2} (on {} nodes)", g.avg_degree(), g.num_nodes()),
        ]);
    }
    Ok(t)
}

/// E4 — §4.3 scaling study: decentralized performance vs crossbars per
/// core, saturating once the node features fit (returns (crossbars,
/// per-node latency, per-node power)).
pub fn scaling_sweep(workload: &GnnWorkload) -> Result<Vec<(usize, Time, f64)>> {
    use crate::config::presets;
    use crate::cores::Accelerator;
    let mut out = Vec::new();
    for k in [1usize, 2, 4, 8, 16, 32] {
        let mut cfg = presets::decentralized();
        // k crossbars per core: the aggregation core splits the feature
        // columns across k parallel crossbars → fewer sequential passes.
        cfg.aggregation.crossbars = k;
        cfg.feature.crossbars = k;
        let acc = Accelerator::new(cfg)?;
        let b = acc.per_node(workload);
        // Parallel column groups: latency of the column-split work divides
        // by min(k, groups); power multiplies by the active banks.
        let groups = workload
            .feature_cells(acc.config().aggregation.geometry.cell_bits)
            .div_ceil(acc.config().aggregation.geometry.cols)
            .max(1);
        let speed = (k.min(groups)) as f64;
        let fe_groups = workload
            .fe_weight_cells(acc.config().feature.geometry.cell_bits)
            .div_ceil(acc.config().feature.geometry.cols)
            .max(1);
        let fe_speed = (k.min(fe_groups)) as f64;
        let latency = b.t1 + b.t2 * (1.0 / speed) + b.t3 * (1.0 / fe_speed);
        let (p1, p2, p3) = b.powers();
        let power = (p1 + p2 * speed + p3 * fe_speed).as_mw();
        out.push((k, latency, power));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn table1_within_one_percent_of_paper() {
        let t = Table1::new().unwrap();
        let err = t.max_relative_error();
        assert!(err < 0.01, "max relative error {err:.4} >= 1%");
        // and the rendered table carries both modeled and paper columns
        let s = t.render().render();
        assert!(s.contains("14.27 µs") && s.contains("Communication"));
    }

    #[test]
    fn fig8_summary_matches_paper_headlines() {
        let f = Fig8::new().unwrap();
        assert_close(f.avg_compute_speedup(), 1400.0, 0.05);
        assert_close(f.avg_comm_speedup(), 790.0, 0.05);
        assert_eq!(f.series.len(), 4);
        assert!(f.summary().contains("paper"));
        assert!(f.render().render().contains("LiveJournal / decentralized"));
    }

    #[test]
    fn table2_renders_all_datasets() {
        let t = table2(2_000).unwrap().render();
        for name in ["LiveJournal", "Collab", "Cora", "Citeseer"] {
            assert!(t.contains(name));
        }
        assert!(t.contains("4847571"));
    }

    #[test]
    fn scaling_improves_then_saturates_and_costs_power() {
        let rows = scaling_sweep(&GnnWorkload::taxi()).unwrap();
        // latency non-increasing
        for w in rows.windows(2) {
            assert!(w[1].1 <= w[0].1, "latency must not increase with crossbars");
            assert!(w[1].2 >= w[0].2, "power must not decrease with crossbars");
        }
        // saturates: taxi has 4 column groups → no gain past 4 crossbars
        let at4 = rows.iter().find(|r| r.0 == 4).unwrap().1;
        let at32 = rows.iter().find(|r| r.0 == 32).unwrap().1;
        assert_close(at4.as_us(), at32.as_us(), 1e-9);
        // but 1 → 4 is a real speedup
        let at1 = rows.iter().find(|r| r.0 == 1).unwrap().1;
        assert!(at1 / at4 > 2.0);
    }
}
