//! Experiment harness: one function per paper table / figure.
//!
//! Shared by the `ima-gnn` CLI and the `rust/benches/*` targets so every
//! artifact is regenerated from exactly one code path (DESIGN.md §4).

use crate::autotune::{
    Autotuner, EvaluatedPoint, OperatingPoint, Partitioner, Score, SettingKind, TuneGrid,
    TunerConfig,
};
use crate::controller::{Controller, CtrlConfig, Hysteresis, SwitchRecord};
use crate::coordinator::{Arrival, GcnLayerBinding, LatencyProvider, RoundEngine, ShardBatch};
use crate::cores::GnnWorkload;
use crate::error::{Error, Result};
use crate::graph::{
    datasets, fixed_size, generate, CompactCsr, Csr, DatasetStats, FeatureQuant, ShardPlan,
};
use crate::netmodel::{NetModel, Setting, Topology};
use crate::netsim::{simulate_fabric, NetSimConfig, Scenario};
use crate::obs::{MetricsRegistry, Obs};
use crate::par;
use crate::report::{pct, speedup, BarSeries, Table};
use crate::sim::{
    CrashImpact, FailoverCostModel, FaultConfig, FaultEvent, FaultKind, FaultPlan, Outage,
};
use crate::testing::{gcn_layer_binding, Rng};
use crate::traffic::{
    deployment_shape, open_loop, open_loop_controlled, open_loop_faulted, open_loop_mix,
    ArrivalProcess, BatchPolicy, DeviceClass, FleetMix, TrafficReport,
};
use crate::units::Time;
use crate::workload::DiurnalCurve;

/// Paper values of Table 1 (for side-by-side reporting).
pub mod paper {
    /// (row, centralized latency s, centralized power W, decentralized
    /// latency s, decentralized power W); `None` power = "-" in the paper.
    pub const TABLE1: &[(&str, f64, Option<f64>, f64, Option<f64>)] = &[
        ("Traversal", 38.43e-9, Some(10.8e-3), 7.68e-9, Some(0.21e-3)),
        ("Aggregation", 142.77e-6, Some(780.1e-3), 14.27e-6, Some(41.6e-3)),
        ("Feature extraction", 14.53e-6, Some(32.21e-3), 0.37e-6, Some(3.68e-3)),
        ("Computation (Net)", 157.34e-6, Some(823.11e-3), 14.6e-6, Some(45.49e-3)),
        ("Communication", 3.30e-3, None, 406e-3, None),
    ];
    pub const FIG8_COMPUTE_SPEEDUP: f64 = 1400.0;
    pub const FIG8_COMM_SPEEDUP: f64 = 790.0;
}

/// E1 — Table 1 rows, modeled vs paper.
pub struct Table1 {
    pub model: NetModel,
    pub topo: Topology,
}

impl Table1 {
    pub fn new() -> Result<Table1> {
        Ok(Table1 { model: NetModel::paper(&GnnWorkload::taxi())?, topo: Topology::taxi() })
    }

    /// Modeled values in paper row order:
    /// (label, cent latency, cent power W, dec latency, dec power W).
    pub fn rows(&self) -> Vec<(String, Time, Option<f64>, Time, Option<f64>)> {
        let m = &self.model;
        let c = m.per_core_latency(Setting::Centralized, self.topo);
        let d = m.per_core_latency(Setting::Decentralized, self.topo);
        let (cp1, cp2, cp3) = m.per_core_power(Setting::Centralized);
        let (dp1, dp2, dp3) = m.per_core_power(Setting::Decentralized);
        vec![
            ("Traversal".into(), c.traversal, Some(cp1.as_w()), d.traversal, Some(dp1.as_w())),
            ("Aggregation".into(), c.aggregation, Some(cp2.as_w()), d.aggregation, Some(dp2.as_w())),
            (
                "Feature extraction".into(),
                c.feature,
                Some(cp3.as_w()),
                d.feature,
                Some(dp3.as_w()),
            ),
            (
                "Computation (Net)".into(),
                c.total(),
                Some(m.compute_power(Setting::Centralized).as_w()),
                d.total(),
                Some(m.compute_power(Setting::Decentralized).as_w()),
            ),
            (
                "Communication".into(),
                m.communicate_latency(Setting::Centralized, self.topo),
                None,
                m.communicate_latency(Setting::Decentralized, self.topo),
                None,
            ),
        ]
    }

    /// Render modeled-vs-paper table.
    pub fn render(&self) -> Table {
        let mut t = Table::new(
            format!(
                "Table 1 — IMA-GNN latency/power (taxi case study, N={}, cs={})",
                self.topo.nodes, self.topo.cluster_size
            ),
            &[
                "Figure of merit",
                "Cent latency",
                "(paper)",
                "Cent power",
                "(paper)",
                "Dec latency",
                "(paper)",
                "Dec power",
                "(paper)",
            ],
        );
        let fmt_p = |w: Option<f64>| {
            w.map(|v| format!("{:.2} mW", v * 1e3)).unwrap_or_else(|| "-".into())
        };
        for (row, paper_row) in self.rows().iter().zip(paper::TABLE1) {
            t.row(&[
                row.0.clone(),
                row.1.to_string(),
                Time::s(paper_row.1).to_string(),
                fmt_p(row.2),
                fmt_p(paper_row.2),
                row.3.to_string(),
                Time::s(paper_row.3).to_string(),
                fmt_p(row.4),
                fmt_p(paper_row.4),
            ]);
        }
        t
    }

    /// Worst relative error vs the paper across all numeric cells.
    pub fn max_relative_error(&self) -> f64 {
        let mut worst: f64 = 0.0;
        for (row, p) in self.rows().iter().zip(paper::TABLE1) {
            worst = worst.max((row.1.as_s() - p.1).abs() / p.1);
            worst = worst.max((row.3.as_s() - p.3).abs() / p.3);
            if let (Some(got), Some(want)) = (row.2, p.2) {
                worst = worst.max((got - want).abs() / want);
            }
            if let (Some(got), Some(want)) = (row.4, p.4) {
                worst = worst.max((got - want).abs() / want);
            }
        }
        worst
    }
}

/// E3 — Fig. 8 series + headline averages.
pub struct Fig8 {
    /// (dataset, centralized (compute, comm), decentralized (compute, comm)).
    pub series: Vec<(String, (Time, Time), (Time, Time))>,
}

impl Fig8 {
    pub fn new() -> Result<Fig8> {
        // One dataset per worker; results land in dataset order (the
        // parallel map is slot-stable), so output is identical to the
        // sequential loop.
        let all = datasets::all();
        type Fig8Row = (String, (Time, Time), (Time, Time));
        let series =
            par::par_try_map(&all, par::available_threads(), |d| -> Result<Fig8Row> {
                let m = NetModel::fig8(d)?;
                let topo = Topology { nodes: d.nodes, cluster_size: d.avg_cs };
                let c = m.latency(Setting::Centralized, topo);
                let dec = m.latency(Setting::Decentralized, topo);
                Ok((
                    d.name.to_string(),
                    (c.compute, c.communicate),
                    (dec.compute, dec.communicate),
                ))
            })?;
        Ok(Fig8 { series })
    }

    /// Average decentralized-compute speedup (paper: ~1400×).
    pub fn avg_compute_speedup(&self) -> f64 {
        self.series.iter().map(|(_, c, d)| c.0 / d.0).sum::<f64>() / self.series.len() as f64
    }

    /// Average centralized-communication speedup (paper: ~790×).
    pub fn avg_comm_speedup(&self) -> f64 {
        self.series.iter().map(|(_, c, d)| d.1 / c.1).sum::<f64>() / self.series.len() as f64
    }

    pub fn render(&self) -> BarSeries {
        let mut b = BarSeries::new(
            "Fig. 8 — computation + communication latency per dataset and setting",
            "s",
        );
        for (name, c, d) in &self.series {
            b.bar(format!("{name} / centralized"), &[("comp", c.0.as_s()), ("comm", c.1.as_s())]);
            b.bar(format!("{name} / decentralized"), &[("comp", d.0.as_s()), ("comm", d.1.as_s())]);
        }
        b
    }

    pub fn summary(&self) -> String {
        format!(
            "decentralized computes {} faster (paper: ~1400x); centralized communicates {} faster (paper: ~790x)",
            speedup(self.avg_compute_speedup()),
            speedup(self.avg_comm_speedup()),
        )
    }
}

/// E2 — Table 2 statistics (published + materialized check).
pub fn table2(materialize_cap: usize) -> Result<Table> {
    let mut t = Table::new(
        "Table 2 — key statistics of the graph datasets",
        &["Dataset", "Nodes", "Edges", "Feature length", "Avg Cs", "materialized avg degree"],
    );
    for d in datasets::all() {
        let g = d.materialize(materialize_cap, 42)?;
        t.row(&[
            d.name.to_string(),
            d.nodes.to_string(),
            d.edges.to_string(),
            d.feature_len.to_string(),
            d.avg_cs.to_string(),
            format!("{:.2} (on {} nodes)", g.avg_degree(), g.num_nodes()),
        ]);
    }
    Ok(t)
}

/// E4 — §4.3 scaling study: decentralized performance vs crossbars per
/// core, saturating once the node features fit (returns (crossbars,
/// per-node latency, per-node power)).
pub fn scaling_sweep(workload: &GnnWorkload) -> Result<Vec<(usize, Time, f64)>> {
    use crate::config::presets;
    use crate::cores::Accelerator;
    // One crossbar count per worker; slot-stable, so row order (and every
    // value) matches the sequential loop.
    let ks = [1usize, 2, 4, 8, 16, 32];
    par::par_try_map(&ks, par::available_threads(), |&k| -> Result<(usize, Time, f64)> {
        let mut cfg = presets::decentralized();
        // k crossbars per core: the aggregation core splits the feature
        // columns across k parallel crossbars → fewer sequential passes.
        cfg.aggregation.crossbars = k;
        cfg.feature.crossbars = k;
        let acc = Accelerator::new(cfg)?;
        let b = acc.per_node(workload);
        // Parallel column groups: latency of the column-split work divides
        // by min(k, groups); power multiplies by the active banks.
        let groups = workload
            .feature_cells(acc.config().aggregation.geometry.cell_bits)
            .div_ceil(acc.config().aggregation.geometry.cols)
            .max(1);
        let speed = (k.min(groups)) as f64;
        let fe_groups = workload
            .fe_weight_cells(acc.config().feature.geometry.cell_bits)
            .div_ceil(acc.config().feature.geometry.cols)
            .max(1);
        let fe_speed = (k.min(fe_groups)) as f64;
        let latency = b.t1 + b.t2 * (1.0 / speed) + b.t3 * (1.0 / fe_speed);
        let (p1, p2, p3) = b.powers();
        let power = (p1 + p2 * speed + p3 * fe_speed).as_mw();
        Ok((k, latency, power))
    })
}

/// One point of the E9 sweep: simulated vs analytic latency for the three
/// deployment fabrics at one (N, cₛ) operating point.
#[derive(Debug, Clone)]
pub struct NetsimRow {
    pub nodes: usize,
    pub cluster_size: usize,
    pub clusters: usize,
    /// (simulated total, analytic Eq. 1 total).
    pub cent: (Time, Time),
    pub dec: (Time, Time),
    /// (simulated total, analytic E8 total); heads are cₛ× a member.
    pub semi: (Time, Time),
    /// Simulated communication portions (the Eq. 4/5 counterparts).
    pub cent_comm: Time,
    pub dec_comm: Time,
}

impl NetsimRow {
    /// Worst simulated-vs-analytic relative gap across the three fabrics.
    pub fn rel_gap(&self) -> f64 {
        [self.cent, self.dec, self.semi]
            .iter()
            .map(|(sim, analytic)| {
                (sim.as_s() - analytic.as_s()).abs() / analytic.as_s().max(1e-30)
            })
            .fold(0.0, f64::max)
    }
}

/// E9 — netsim cluster-count × graph-scale sweep: the packet fabric run
/// over every (N, cₛ) pair, reporting the centralized-vs-decentralized
/// comm/compute gap and the semi-decentralized crossover (the operating
/// point where the hybrid beats both extremes).
pub struct NetsimSweep {
    pub rows: Vec<NetsimRow>,
    pub cfg: NetSimConfig,
}

impl NetsimSweep {
    /// Default grid: the taxi workload over 1k–10k devices, cₛ 5–50.
    pub fn paper_grid(cfg: &NetSimConfig) -> Result<NetsimSweep> {
        NetsimSweep::run(
            &GnnWorkload::taxi(),
            &[1_000, 2_000, 5_000, 10_000],
            &[5, 10, 25, 50],
            cfg,
        )
    }

    /// Run the grid over all available cores.  Every grid point seeds its
    /// own RNG from the config, and the parallel map writes results by
    /// slot index, so the sweep (and its `to_json` bytes) is identical to
    /// the sequential `run_with_threads(.., 1)` — asserted in tests.
    pub fn run(
        workload: &GnnWorkload,
        nodes_list: &[usize],
        cluster_sizes: &[usize],
        cfg: &NetSimConfig,
    ) -> Result<NetsimSweep> {
        NetsimSweep::run_with_threads(
            workload,
            nodes_list,
            cluster_sizes,
            cfg,
            par::available_threads(),
        )
    }

    /// [`Self::run`] with an explicit worker count (1 = sequential).
    pub fn run_with_threads(
        workload: &GnnWorkload,
        nodes_list: &[usize],
        cluster_sizes: &[usize],
        cfg: &NetSimConfig,
        threads: usize,
    ) -> Result<NetsimSweep> {
        let model = NetModel::paper(workload)?;
        let mut points = Vec::with_capacity(nodes_list.len() * cluster_sizes.len());
        for &nodes in nodes_list {
            for &cluster_size in cluster_sizes {
                if cluster_size == 0 || cluster_size >= nodes {
                    continue;
                }
                points.push((nodes, cluster_size));
            }
        }
        let rows = par::par_try_map(&points, threads, |&(nodes, cluster_size)| -> Result<NetsimRow> {
            let topo = Topology { nodes, cluster_size };
            let head = cluster_size as f64;
            let cent = simulate_fabric(&model, Scenario::CentralizedStar, topo, cfg)?;
            let dec = simulate_fabric(&model, Scenario::DecentralizedMesh, topo, cfg)?;
            let semi = simulate_fabric(
                &model,
                Scenario::SemiOverlay { head_capacity: head },
                topo,
                cfg,
            )?;
            Ok(NetsimRow {
                nodes,
                cluster_size,
                clusters: nodes.div_ceil(cluster_size),
                cent: (cent.completion, model.latency(Setting::Centralized, topo).total()),
                dec: (dec.completion, model.latency(Setting::Decentralized, topo).total()),
                semi: (semi.completion, model.semi_latency(topo, head).total()),
                cent_comm: cent.comm_done,
                dec_comm: dec.comm_done,
            })
        })?;
        Ok(NetsimSweep { rows, cfg: cfg.clone() })
    }

    /// The first operating point (scan order: growing N, then cₛ) where
    /// the simulated hybrid beats both extremes.
    pub fn crossover(&self) -> Option<&NetsimRow> {
        self.rows.iter().find(|r| r.semi.0 < r.cent.0 && r.semi.0 < r.dec.0)
    }

    /// Average centralized-over-decentralized communication advantage
    /// (simulated; the Fig. 8 ~790× axis at the swept operating points).
    pub fn avg_comm_gap(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows.iter().map(|r| r.dec_comm / r.cent_comm).sum::<f64>()
            / self.rows.len() as f64
    }

    /// Average decentralized-over-centralized compute advantage
    /// (simulated completion minus communication).
    pub fn avg_compute_gap(&self) -> f64 {
        if self.rows.is_empty() {
            return 0.0;
        }
        self.rows
            .iter()
            .map(|r| {
                let cent = (r.cent.0 - r.cent_comm).as_s().max(1e-30);
                let dec = (r.dec.0 - r.dec_comm).as_s().max(1e-30);
                cent / dec
            })
            .sum::<f64>()
            / self.rows.len() as f64
    }

    /// Worst simulated-vs-analytic gap across every row and fabric
    /// (≈0 for an uncongested config — the cross-validation invariant).
    pub fn max_rel_gap(&self) -> f64 {
        self.rows.iter().map(NetsimRow::rel_gap).fold(0.0, f64::max)
    }

    /// Post-hoc metrics view of the sweep — the `.metrics.json` sidecar
    /// the CLI writes next to `BENCH_netsim.json`.  A pure function of
    /// the rows, so it inherits the sweep's byte-determinism.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        let m = MetricsRegistry::new();
        m.inc("netsim.rows", self.rows.len() as u64);
        m.set_gauge("netsim.max_rel_gap", self.max_rel_gap());
        m.set_gauge("netsim.avg_comm_gap", self.avg_comm_gap());
        m.set_gauge("netsim.avg_compute_gap", self.avg_compute_gap());
        if self.crossover().is_some() {
            m.inc("netsim.crossovers", 1);
        }
        for r in &self.rows {
            m.observe("netsim.centralized_total_s", r.cent.0.as_s());
            m.observe("netsim.decentralized_total_s", r.dec.0.as_s());
            m.observe("netsim.semi_total_s", r.semi.0.as_s());
        }
        m
    }

    pub fn render(&self) -> Table {
        let mut t = Table::new(
            "E9 — netsim sweep: simulated (analytic) round latency per fabric",
            &["N", "cs", "Centralized", "Decentralized", "Semi (head=cs)", "Winner"],
        );
        let cell = |p: (Time, Time)| format!("{} ({})", p.0, p.1);
        for r in &self.rows {
            let winner = if r.semi.0 < r.cent.0 && r.semi.0 < r.dec.0 {
                "semi"
            } else if r.cent.0 < r.dec.0 {
                "centralized"
            } else {
                "decentralized"
            };
            t.row(&[
                r.nodes.to_string(),
                r.cluster_size.to_string(),
                cell(r.cent),
                cell(r.dec),
                cell(r.semi),
                winner.into(),
            ]);
        }
        t
    }

    /// The `BENCH_netsim.json` artifact: per-scenario simulated vs
    /// analytic latency plus the sweep summary, for tracking the perf
    /// trajectory across PRs.
    pub fn to_json(&self) -> String {
        let num = |v: f64| format!("{v:.6e}");
        let mut rows = Vec::with_capacity(self.rows.len());
        for r in &self.rows {
            rows.push(format!(
                "    {{\"nodes\": {}, \"cluster_size\": {}, \"clusters\": {}, \
                 \"centralized\": {{\"simulated_s\": {}, \"analytic_s\": {}, \"comm_s\": {}}}, \
                 \"decentralized\": {{\"simulated_s\": {}, \"analytic_s\": {}, \"comm_s\": {}}}, \
                 \"semi\": {{\"simulated_s\": {}, \"analytic_s\": {}}}}}",
                r.nodes,
                r.cluster_size,
                r.clusters,
                num(r.cent.0.as_s()),
                num(r.cent.1.as_s()),
                num(r.cent_comm.as_s()),
                num(r.dec.0.as_s()),
                num(r.dec.1.as_s()),
                num(r.dec_comm.as_s()),
                num(r.semi.0.as_s()),
                num(r.semi.1.as_s()),
            ));
        }
        let crossover = match self.crossover() {
            Some(r) => format!(
                "{{\"nodes\": {}, \"cluster_size\": {}}}",
                r.nodes, r.cluster_size
            ),
            None => "null".into(),
        };
        let ports = match self.cfg.rx_ports {
            Some(p) => p.to_string(),
            None => "null".into(),
        };
        let channels = match self.cfg.cluster_channels {
            Some(c) => c.to_string(),
            None => "null".into(),
        };
        format!(
            "{{\n  \"experiment\": \"netsim_sweep\",\n  \"config\": {{\"rx_ports\": {}, \
             \"cluster_channels\": {}, \"hops\": {}, \"link_jitter\": {}, \"seed\": {}}},\n  \
             \"summary\": {{\"max_rel_gap\": {}, \"avg_comm_gap\": {}, \
             \"avg_compute_gap\": {}, \"crossover\": {}}},\n  \"rows\": [\n{}\n  ]\n}}\n",
            ports,
            channels,
            self.cfg.hops,
            num(self.cfg.link_jitter),
            self.cfg.seed,
            num(self.max_rel_gap()),
            num(self.avg_comm_gap()),
            num(self.avg_compute_gap()),
            crossover,
            rows.join(",\n"),
        )
    }
}

/// One target of the E11 hybrid sweep: a Table 2 dataset or the §4.2
/// taxi case study.
#[derive(Debug, Clone)]
enum HybridTarget {
    Dataset(DatasetStats),
    Taxi,
}

impl HybridTarget {
    /// (name, deployment N, network model, materialized sample graph).
    fn instantiate(&self, cap: usize) -> Result<(String, usize, NetModel, Csr)> {
        match self {
            HybridTarget::Dataset(d) => Ok((
                d.name.to_string(),
                d.nodes,
                NetModel::fig8(d)?,
                d.materialize(cap, 42)?,
            )),
            HybridTarget::Taxi => {
                // Road-grid substrate for the locality partitioner, capped
                // like the dataset samples.
                let cols = 50.min(cap.max(2));
                let rows = (cap / cols).max(1);
                Ok((
                    "Taxi".into(),
                    10_000,
                    NetModel::paper(&GnnWorkload::taxi())?,
                    generate::grid(rows, cols)?,
                ))
            }
        }
    }

    fn avg_cs(&self) -> usize {
        match self {
            HybridTarget::Dataset(d) => d.avg_cs,
            HybridTarget::Taxi => 10,
        }
    }
}

/// Resolve one E11 target by name (`taxi` or a Table 2 dataset) into
/// (display name, deployment N, network model, materialized sample) —
/// the `ima-gnn tune --dataset` entry point.
pub fn hybrid_target(name: &str, materialize_cap: usize) -> Result<(String, usize, NetModel, Csr)> {
    let target = if name.eq_ignore_ascii_case("taxi") {
        HybridTarget::Taxi
    } else {
        HybridTarget::Dataset(datasets::by_name(name)?)
    };
    target.instantiate(materialize_cap)
}

/// One dataset row of the E11 sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct HybridRow {
    pub dataset: String,
    /// Deployment scale N the points were scored at.
    pub nodes: usize,
    pub message_bytes: usize,
    /// The autotuner's argmin.
    pub best: EvaluatedPoint,
    /// Pure-setting baselines: the canonical centralized point and
    /// decentralized at the dataset's published Avg Cₛ (fixed blocking).
    pub pure_cent: Score,
    pub pure_dec: Score,
    pub pure_dec_cs: usize,
    pub grid_points: usize,
    pub pareto_points: usize,
}

impl HybridRow {
    /// The paper-conclusion claim at this operating region: the tuned
    /// *hybrid* strictly beats both pure settings on total latency.
    pub fn hybrid_wins(&self) -> bool {
        self.best.point.setting == SettingKind::Semi
            && self.best.score.latency < self.pure_cent.latency
            && self.best.score.latency < self.pure_dec.latency
    }

    /// Tuned-vs-best-pure latency advantage (≥ 1 by construction when the
    /// pure points are inside the searched grid region).
    pub fn speedup_vs_best_pure(&self) -> f64 {
        self.pure_cent.latency.min(self.pure_dec.latency) / self.best.score.latency
    }
}

/// E11 — hybrid operating-point autotuner sweep over the four Table 2
/// datasets + the taxi case study, emitting `BENCH_hybrid.json`.
///
/// The sweep is driven by `par::par_try_map`; every score is a pure
/// function of (model, sample, point), so the parallel output is
/// byte-identical to the sequential run (asserted in tests).
pub struct HybridSweep {
    pub rows: Vec<HybridRow>,
    pub materialize_cap: usize,
}

impl HybridSweep {
    /// The E11 grid: three settings × cₛ ∈ {4, 8, 10, 16, 32} ×
    /// head capacity ∈ {4, 10, 25} × both partitioners (41 points).
    pub fn paper_grid() -> TuneGrid {
        TuneGrid::full(&[4, 8, 10, 16, 32], &[4.0, 10.0, 25.0])
    }

    pub fn run(materialize_cap: usize) -> Result<HybridSweep> {
        HybridSweep::run_with_threads(materialize_cap, par::available_threads())
    }

    /// [`Self::run`] with an explicit worker count (1 = sequential) and
    /// the default 3 netsim cross-checks per target.
    pub fn run_with_threads(materialize_cap: usize, threads: usize) -> Result<HybridSweep> {
        HybridSweep::run_configured(materialize_cap, threads, 3)
    }

    /// Fully parameterized sweep: `netsim_refine` packet-level
    /// cross-checks of each target's best points (0 = analytic only).
    pub fn run_configured(
        materialize_cap: usize,
        threads: usize,
        netsim_refine: usize,
    ) -> Result<HybridSweep> {
        let targets: Vec<HybridTarget> = datasets::all()
            .into_iter()
            .map(HybridTarget::Dataset)
            .chain(std::iter::once(HybridTarget::Taxi))
            .collect();
        let rows = par::par_try_map(&targets, threads, |t| -> Result<HybridRow> {
            let (name, nodes, model, sample) = t.instantiate(materialize_cap)?;
            let tuner = Autotuner::new(
                &model,
                &sample,
                nodes,
                HybridSweep::paper_grid(),
                TunerConfig {
                    netsim_refine,
                    netsim_nodes_cap: materialize_cap,
                    ..Default::default()
                },
            )?;
            // Datasets fan out across workers; each explore stays
            // sequential so the two levels do not oversubscribe.
            let out = tuner.explore_with_threads(1)?;
            let pure_cent = tuner.score(&OperatingPoint::centralized())?.score;
            let pure_dec_cs = t.avg_cs();
            let pure_dec = tuner
                .score(&OperatingPoint::decentralized(pure_dec_cs, Partitioner::FixedSize))?
                .score;
            Ok(HybridRow {
                dataset: name,
                nodes,
                message_bytes: model.message_bytes(),
                best: out.best_point().clone(),
                pure_cent,
                pure_dec,
                pure_dec_cs,
                grid_points: out.evaluated.len(),
                pareto_points: out.pareto.len(),
            })
        })?;
        Ok(HybridSweep { rows, materialize_cap })
    }

    /// Rows where the tuned hybrid beats both pure settings.
    pub fn hybrid_wins(&self) -> Vec<&HybridRow> {
        self.rows.iter().filter(|r| r.hybrid_wins()).collect()
    }

    /// Post-hoc metrics view of the sweep — the `.metrics.json` sidecar
    /// the CLI writes next to `BENCH_hybrid.json`.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        let m = MetricsRegistry::new();
        m.inc("hybrid.datasets", self.rows.len() as u64);
        m.inc("hybrid.wins", self.hybrid_wins().len() as u64);
        for r in &self.rows {
            m.inc("hybrid.grid_points", r.grid_points as u64);
            m.observe("hybrid.best_latency_s", r.best.score.latency.as_s());
            m.observe("hybrid.speedup_vs_best_pure", r.speedup_vs_best_pure());
        }
        m
    }

    pub fn render(&self) -> Table {
        let mut t = Table::new(
            "E11 — tuned operating point vs pure settings (total round latency)",
            &[
                "Dataset",
                "N",
                "Best point",
                "Best latency",
                "Centralized",
                "Dec (Avg Cs)",
                "vs best pure",
                "Intra-edge",
            ],
        );
        for r in &self.rows {
            t.row(&[
                r.dataset.clone(),
                r.nodes.to_string(),
                r.best.point.label(),
                r.best.score.latency.to_string(),
                r.pure_cent.latency.to_string(),
                format!("{} (cs={})", r.pure_dec.latency, r.pure_dec_cs),
                speedup(r.speedup_vs_best_pure()),
                pct(r.best.facts.intra_fraction),
            ]);
        }
        t
    }

    /// The `BENCH_hybrid.json` artifact.
    pub fn to_json(&self) -> String {
        let num = |v: f64| format!("{v:.6e}");
        let grid = HybridSweep::paper_grid();
        let list = |xs: &[String]| xs.join(", ");
        let cs: Vec<String> = grid.cluster_sizes.iter().map(|c| c.to_string()).collect();
        let hs: Vec<String> = grid.head_capacities.iter().map(|h| num(*h)).collect();
        let mut rows = Vec::with_capacity(self.rows.len());
        for r in &self.rows {
            let b = &r.best;
            let check = match &b.simulated {
                Some(s) => format!(
                    "{{\"nodes\": {}, \"simulated_s\": {}, \"analytic_s\": {}}}",
                    s.nodes,
                    num(s.simulated.as_s()),
                    num(s.analytic.as_s())
                ),
                None => "null".into(),
            };
            rows.push(format!(
                "    {{\"dataset\": \"{}\", \"nodes\": {}, \"message_bytes\": {}, \
                 \"best\": {{\"setting\": \"{}\", \"cluster_size\": {}, \
                 \"head_capacity\": {}, \"partitioner\": \"{}\", \"latency_s\": {}, \
                 \"energy_j\": {}, \"per_device_power_w\": {}, \"intra_fraction\": {}, \
                 \"max_cluster\": {}}}, \
                 \"pure\": {{\"centralized_latency_s\": {}, \
                 \"decentralized_latency_s\": {}, \"decentralized_cs\": {}}}, \
                 \"hybrid_wins\": {}, \"speedup_vs_best_pure\": {}, \
                 \"grid_points\": {}, \"pareto_points\": {}, \"netsim_check\": {}}}",
                r.dataset,
                r.nodes,
                r.message_bytes,
                b.point.setting.name(),
                b.point.cluster_size,
                num(b.point.head_capacity),
                b.point.partitioner.name(),
                num(b.score.latency.as_s()),
                num(b.score.energy.as_j()),
                num(b.score.per_device_power.as_w()),
                num(b.facts.intra_fraction),
                b.facts.max_size,
                num(r.pure_cent.latency.as_s()),
                num(r.pure_dec.latency.as_s()),
                r.pure_dec_cs,
                r.hybrid_wins(),
                num(r.speedup_vs_best_pure()),
                r.grid_points,
                r.pareto_points,
                check,
            ));
        }
        let wins: Vec<String> = self
            .hybrid_wins()
            .iter()
            .map(|r| format!("\"{}\"", r.dataset))
            .collect();
        format!(
            "{{\n  \"experiment\": \"hybrid_autotune\",\n  \"materialize_cap\": {},\n  \
             \"grid\": {{\"cluster_sizes\": [{}], \"head_capacities\": [{}], \
             \"partitioners\": [\"fixed_size\", \"locality\"], \
             \"settings\": [\"centralized\", \"semi\", \"decentralized\"]}},\n  \
             \"summary\": {{\"datasets\": {}, \"hybrid_wins\": [{}]}},\n  \"rows\": [\n{}\n  ]\n}}\n",
            self.materialize_cap,
            list(&cs),
            list(&hs),
            self.rows.len(),
            list(&wins),
            rows.join(",\n"),
        )
    }
}

/// One dataset row of the E12 sharded-serving sweep.  Every field except
/// `wall_s` is a deterministic pure function of (dataset, cap, rounds),
/// which is what the parallel byte-identical assertion relies on; the
/// wall measurement is attached only in timed runs.
#[derive(Debug, Clone, PartialEq)]
pub struct ServingRow {
    pub dataset: String,
    /// Materialized sample size actually sharded and round-driven.
    pub sample_nodes: usize,
    /// Published deployment scale the round latencies are modeled at.
    pub deploy_nodes: usize,
    /// Serving cluster size: the dataset's Avg Cₛ capped to the shard
    /// feasibility bound `table / (1 + sample)`.
    pub cluster_size: usize,
    pub table: usize,
    pub shards: usize,
    pub max_halo: usize,
    pub max_slots: usize,
    /// PJRT batches one full round (every node served once) costs.
    pub batches_per_round: u64,
    /// Round barriers driven per dataset.
    pub rounds: usize,
    /// Table-tensor cache misses over the run (= shards × rounds — the
    /// engine's round-constant guarantee, asserted in tests).
    pub table_builds: u64,
    /// Modeled round latency at deployment scale, centralized Eq. 1.
    pub cent_modeled: Time,
    /// Modeled round latency, boundary-aware clustered E8 (heads cₛ×).
    pub semi_modeled: Time,
    /// Wall-clock of the `rounds` upload → barrier → assemble rounds
    /// (`None` in untimed determinism runs).
    pub wall_s: Option<f64>,
}

/// E12 — sharded serving sweep: the four Table 2 dataset shapes + the
/// taxi study driven through the [`RoundEngine`] at artifact-table
/// granularity (the 64-row test binding), emitting `BENCH_serving.json`.
///
/// Each dataset materializes a capped sample, shards it with whole
/// serving clusters per shard, and runs `rounds` full upload → barrier →
/// assemble rounds; the row records the shard geometry, the per-round
/// batch count, the tensor-cache miss count and the modeled round
/// latencies at deployment scale.  Rows are computed via
/// `par::par_try_map`; untimed output is byte-identical to the
/// sequential run (asserted in tests).
pub struct ServingSweep {
    pub rows: Vec<ServingRow>,
    pub materialize_cap: usize,
    pub rounds: usize,
}

impl ServingSweep {
    /// Timed sweep over all available cores (the CLI / CI entry point).
    pub fn run(materialize_cap: usize, rounds: usize) -> Result<ServingSweep> {
        ServingSweep::run_with_threads(materialize_cap, rounds, par::available_threads(), true)
    }

    /// Fully parameterized sweep; `timed = false` drops the wall field so
    /// the output is reproducible bit-for-bit across thread counts.
    pub fn run_with_threads(
        materialize_cap: usize,
        rounds: usize,
        threads: usize,
        timed: bool,
    ) -> Result<ServingSweep> {
        let targets: Vec<HybridTarget> = datasets::all()
            .into_iter()
            .map(HybridTarget::Dataset)
            .chain(std::iter::once(HybridTarget::Taxi))
            .collect();
        let rows = par::par_try_map(&targets, threads, |t| {
            ServingSweep::row(t, materialize_cap, rounds, timed)
        })?;
        Ok(ServingSweep { rows, materialize_cap, rounds })
    }

    fn row(
        target: &HybridTarget,
        cap: usize,
        rounds: usize,
        timed: bool,
    ) -> Result<ServingRow> {
        let (name, deploy_nodes, model, sample) = target.instantiate(cap)?;
        let binding = gcn_layer_binding();
        // A whole serving cluster must fit a shard next to its halo; one
        // member can sample at most `sample` halo rows, so this bound is
        // always packable.
        let cs = target.avg_cs().clamp(1, binding.table / (1 + binding.sample));
        let n = sample.num_nodes();
        let clustering = fixed_size(n, cs)?;
        let plan =
            ShardPlan::from_clustering(&sample, &binding.sampler(), binding.table, &clustering)?;
        let (feature, hidden, table) = (binding.feature, binding.hidden, binding.table);
        let mut engine = RoundEngine::new(binding, plan, vec![0.01; feature * hidden])?;
        let all: Vec<usize> = (0..n).collect();
        // Synthetic per-round features are drawn OUTSIDE the timed window
        // so `wall_s` measures the engine (upload → barrier → assemble),
        // not the test RNG.
        let round_features: Vec<Vec<f32>> = (0..rounds)
            .map(|round| {
                let mut rng = Rng::new(0xE12 + round as u64);
                (0..n * feature).map(|_| rng.f64() as f32).collect()
            })
            .collect();
        let mut batches_per_round = 0u64;
        let t0 = std::time::Instant::now();
        for feats in &round_features {
            for node in 0..n {
                engine.upload(node, &feats[node * feature..(node + 1) * feature])?;
            }
            engine.end_round();
            batches_per_round = engine.assemble(&all)?.len() as u64;
        }
        let wall = t0.elapsed().as_secs_f64();
        let intra = clustering.intra_edge_fraction(&sample);
        let topo = Topology { nodes: deploy_nodes, cluster_size: cs };
        Ok(ServingRow {
            dataset: name,
            sample_nodes: n,
            deploy_nodes,
            cluster_size: cs,
            table,
            shards: engine.plan().num_shards(),
            max_halo: engine.plan().max_halo(),
            max_slots: engine.plan().max_slots(),
            batches_per_round,
            rounds,
            table_builds: engine.table_builds(),
            cent_modeled: LatencyProvider::Analytic.centralized(&model, topo),
            semi_modeled: LatencyProvider::Clustered { intra_fraction: intra }
                .semi(&model, topo, cs as f64),
            wall_s: timed.then_some(wall),
        })
    }

    /// Post-hoc metrics view of the sweep — the `.metrics.json` sidecar
    /// the CLI writes next to `BENCH_serving.json`.  Wall-clock fields are
    /// deliberately excluded so the snapshot stays byte-deterministic.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        let m = MetricsRegistry::new();
        m.inc("serving.datasets", self.rows.len() as u64);
        for r in &self.rows {
            m.inc("serving.table_builds", r.table_builds);
            m.inc("serving.batches_per_round", r.batches_per_round);
            m.raise_gauge("serving.max_slots", r.max_slots as f64);
            m.observe("serving.cent_modeled_s", r.cent_modeled.as_s());
            m.observe("serving.semi_modeled_s", r.semi_modeled.as_s());
        }
        m
    }

    pub fn render(&self) -> Table {
        let mut t = Table::new(
            "E12 — sharded serving: Table 2 shapes through one round engine",
            &[
                "Dataset",
                "Sample N",
                "cs",
                "Shards",
                "Max halo",
                "Batches/round",
                "Cent modeled",
                "Semi modeled",
                "Wall",
            ],
        );
        for r in &self.rows {
            t.row(&[
                r.dataset.clone(),
                r.sample_nodes.to_string(),
                r.cluster_size.to_string(),
                r.shards.to_string(),
                r.max_halo.to_string(),
                r.batches_per_round.to_string(),
                r.cent_modeled.to_string(),
                r.semi_modeled.to_string(),
                r.wall_s
                    .map(|w| format!("{:.1} ms", w * 1e3))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    /// The `BENCH_serving.json` artifact.
    pub fn to_json(&self) -> String {
        let num = |v: f64| format!("{v:.6e}");
        let mut rows = Vec::with_capacity(self.rows.len());
        for r in &self.rows {
            let wall = match r.wall_s {
                Some(w) => num(w),
                None => "null".into(),
            };
            rows.push(format!(
                "    {{\"dataset\": \"{}\", \"sample_nodes\": {}, \"deploy_nodes\": {}, \
                 \"cluster_size\": {}, \"table\": {}, \"shards\": {}, \"max_halo\": {}, \
                 \"max_slots\": {}, \"batches_per_round\": {}, \"rounds\": {}, \
                 \"table_builds\": {}, \"modeled\": {{\"centralized_s\": {}, \
                 \"semi_s\": {}}}, \"wall_s\": {}}}",
                r.dataset,
                r.sample_nodes,
                r.deploy_nodes,
                r.cluster_size,
                r.table,
                r.shards,
                r.max_halo,
                r.max_slots,
                r.batches_per_round,
                r.rounds,
                r.table_builds,
                num(r.cent_modeled.as_s()),
                num(r.semi_modeled.as_s()),
                wall,
            ));
        }
        let sharded = self.rows.iter().filter(|r| r.shards > 1).count();
        format!(
            "{{\n  \"experiment\": \"sharded_serving\",\n  \"materialize_cap\": {},\n  \
             \"rounds\": {},\n  \"summary\": {{\"datasets\": {}, \"sharded_datasets\": {}}},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            self.materialize_cap,
            self.rounds,
            self.rows.len(),
            sharded,
            rows.join(",\n"),
        )
    }
}

/// E13 batching policy: the artifact batch with a short coalescing
/// deadline (the serving batcher's defaults, in virtual time).
pub const TRAFFIC_MAX_BATCH: usize = 64;
/// E13 batch-coalescing deadline (ms).
pub const TRAFFIC_WAIT_MS: f64 = 2.0;
/// E13 response-latency SLO (ms) the attainment column reports against.
pub const TRAFFIC_SLO_MS: f64 = 25.0;
/// E13 offered-rate grid, as fractions of the centralized leader's
/// saturation rate (`ServiceModel::saturation_rate` at the full batch).
pub const TRAFFIC_REL_RATES: [f64; 6] = [0.1, 0.3, 0.6, 0.9, 1.2, 2.0];

/// One (dataset, rate, setting) point of the E13 traffic sweep.  All
/// fields are pure functions of the point's seed and config — the
/// parallel byte-identical contract.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficPoint {
    /// `centralized` | `semi` | `decentralized`.
    pub setting: &'static str,
    /// Offered system rate as a fraction of centralized saturation.
    pub rel_rate: f64,
    /// Offered system-wide rate (requests/second over the whole fleet).
    pub rate_per_s: f64,
    /// Rate the simulated representative queue sees (exact uniform
    /// Poisson split over the shape's queues).
    pub queue_rate_per_s: f64,
    /// Queues in the full shape (leader: 1; semi: cluster heads;
    /// decentralized: devices).
    pub servers_total: usize,
    pub offered: usize,
    pub utilization: f64,
    pub mean_wait_s: f64,
    pub mean_batch: f64,
    pub max_queue_depth: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    /// Fraction of responses within the E13 SLO.
    pub slo_attainment: f64,
    /// Little's-law residual (round-off on a correct engine; asserted
    /// on every point in `rust/tests/traffic_cross_validation.rs`).
    pub littles_gap: f64,
}

/// One dataset row of the E13 sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct TrafficRow {
    pub dataset: String,
    pub nodes: usize,
    pub cluster_size: usize,
    /// Cluster-head queues in the semi shape.
    pub clusters: usize,
    /// Intra-edge fraction of the capped sample's fixed-size clustering
    /// (feeds the Clustered latency provider for semi/decentralized).
    pub intra_fraction: f64,
    /// Centralized saturation rate the grid normalizes against.
    pub sat_rate_per_s: f64,
    /// `TRAFFIC_REL_RATES × {centralized, semi, decentralized}` points,
    /// rate-major.
    pub points: Vec<TrafficPoint>,
    /// First swept rate where the semi overlay's p95 beats the leader's
    /// — the "at what request rate does semi overtake centralized?"
    /// answer (requests/second; `None` if the leader never loses).
    pub crossover_per_s: Option<f64>,
}

impl TrafficRow {
    /// The point for (`rel_rate` index, setting name).
    pub fn point(&self, rel_idx: usize, setting: &str) -> &TrafficPoint {
        self.points
            .iter()
            .find(|p| p.setting == setting && p.rel_rate == TRAFFIC_REL_RATES[rel_idx])
            .expect("sweep emits every (rate, setting) point")
    }
}

/// E13 — arrival-driven traffic sweep: the four Table 2 datasets driven
/// by open-loop Poisson streams (load that does not back off under
/// congestion) across `TRAFFIC_REL_RATES`, each deployment
/// shape queueing per its topology (leader / cluster heads / devices),
/// batching under the size-or-deadline policy and serving at the
/// boundary-aware modeled round latencies.  Emits `BENCH_traffic.json`.
///
/// Each shape simulates one representative queue at the exact uniform
/// Poisson split of the system rate (`DeploymentQueues::per_queue_rate`)
/// — servers are independent, so the per-queue latency distribution is
/// the system's.  Rows are computed via `par::par_try_map`; output is
/// byte-identical to the sequential run (asserted in tests).
pub struct TrafficSweep {
    pub rows: Vec<TrafficRow>,
    pub materialize_cap: usize,
    /// Target requests simulated per point (the Poisson stream's
    /// expected count).
    pub requests: usize,
}

impl TrafficSweep {
    pub fn run(materialize_cap: usize, requests: usize) -> Result<TrafficSweep> {
        TrafficSweep::run_with_threads(materialize_cap, requests, par::available_threads())
    }

    /// [`Self::run`] with an explicit worker count (1 = sequential).
    pub fn run_with_threads(
        materialize_cap: usize,
        requests: usize,
        threads: usize,
    ) -> Result<TrafficSweep> {
        if requests == 0 {
            return Err(crate::error::Error::Sim("traffic sweep needs requests > 0".into()));
        }
        let all = datasets::all();
        let targets: Vec<(usize, DatasetStats)> = all.into_iter().enumerate().collect();
        let rows = par::par_try_map(&targets, threads, |(di, d)| {
            TrafficSweep::row(*di, d, materialize_cap, requests)
        })?;
        Ok(TrafficSweep { rows, materialize_cap, requests })
    }

    fn row(
        di: usize,
        d: &DatasetStats,
        cap: usize,
        requests: usize,
    ) -> Result<TrafficRow> {
        let model = NetModel::fig8(d)?;
        let topo = Topology { nodes: d.nodes, cluster_size: d.avg_cs };
        // Boundary realism: the capped sample's fixed-size clustering
        // supplies the intra-edge fraction the Clustered provider scales
        // the semi / decentralized exchanges by (the E11 model).
        let sample = d.materialize(cap, 42)?;
        let cs_sample = d.avg_cs.clamp(1, sample.num_nodes());
        let clustering = fixed_size(sample.num_nodes(), cs_sample)?;
        let intra = clustering.intra_edge_fraction(&sample);
        let clustered = LatencyProvider::Clustered { intra_fraction: intra };

        // One shape constructor for sweep/CLI/examples; the centralized
        // gather ignores the cluster structure, so passing the clustered
        // provider uniformly prices exactly Analytic for the leader.
        let mut shapes = Vec::with_capacity(3);
        for kind in
            [SettingKind::Centralized, SettingKind::Semi, SettingKind::Decentralized]
        {
            let (queues, service) = deployment_shape(kind, clustered, &model, topo)?;
            shapes.push((kind.name(), queues, service));
        }
        let clusters = shapes[1].1.servers();
        let sat = shapes[0].2.saturation_rate(TRAFFIC_MAX_BATCH);
        let policy = BatchPolicy::Deadline {
            max: TRAFFIC_MAX_BATCH,
            max_wait: Time::ms(TRAFFIC_WAIT_MS),
        };

        let mut points = Vec::with_capacity(TRAFFIC_REL_RATES.len() * shapes.len());
        for (ri, &rel) in TRAFFIC_REL_RATES.iter().enumerate() {
            let rate = rel * sat;
            for (si, &(name, queues, service)) in shapes.iter().enumerate() {
                let queue_rate = queues.per_queue_rate(rate);
                let horizon = Time::s(requests as f64 / queue_rate);
                let seed = 0xE13_000 + (di as u64) * 64 + (ri as u64) * 8 + si as u64;
                let arrivals = ArrivalProcess::Poisson { rate: queue_rate }
                    .generate(horizon, d.nodes, seed)?;
                let r = open_loop(1, &service, policy, &arrivals)?;
                points.push(TrafficPoint {
                    setting: name,
                    rel_rate: rel,
                    rate_per_s: rate,
                    queue_rate_per_s: queue_rate,
                    servers_total: queues.servers(),
                    offered: r.offered,
                    utilization: r.utilization,
                    mean_wait_s: r.mean_wait.as_s(),
                    mean_batch: r.mean_batch,
                    max_queue_depth: r.max_queue_depth,
                    mean_s: r.latency.mean().as_s(),
                    p50_s: r.latency.p50().as_s(),
                    p95_s: r.latency.p95().as_s(),
                    p99_s: r.latency.p99().as_s(),
                    slo_attainment: r.slo_attainment(Time::ms(TRAFFIC_SLO_MS)),
                    littles_gap: r.littles_law_gap(),
                });
            }
        }
        let crossover_per_s = TRAFFIC_REL_RATES.iter().find_map(|&rel| {
            let p95_at = |s: &str| {
                points
                    .iter()
                    .find(|p| p.setting == s && p.rel_rate == rel)
                    .expect("sweep emits every (rate, setting) point")
                    .p95_s
            };
            (p95_at("semi") < p95_at("centralized")).then_some(rel * sat)
        });
        Ok(TrafficRow {
            dataset: d.name.to_string(),
            nodes: d.nodes,
            cluster_size: d.avg_cs,
            clusters,
            intra_fraction: intra,
            sat_rate_per_s: sat,
            points,
            crossover_per_s,
        })
    }

    /// Worst Little's-law residual across every point (round-off).
    pub fn max_littles_gap(&self) -> f64 {
        self.rows
            .iter()
            .flat_map(|r| r.points.iter().map(|p| p.littles_gap))
            .fold(0.0, f64::max)
    }

    /// Post-hoc metrics view of the sweep — the `.metrics.json` sidecar
    /// the CLI writes next to `BENCH_traffic.json`.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        let m = MetricsRegistry::new();
        m.inc("traffic.datasets", self.rows.len() as u64);
        m.set_gauge("traffic.max_littles_gap", self.max_littles_gap());
        for r in &self.rows {
            for p in &r.points {
                m.inc("traffic.points", 1);
                m.inc("traffic.offered", p.offered as u64);
                m.raise_gauge("traffic.max_queue_depth", p.max_queue_depth as f64);
                m.observe("traffic.p95_s", p.p95_s);
                m.observe("traffic.utilization", p.utilization);
            }
            if r.crossover_per_s.is_some() {
                m.inc("traffic.crossovers", 1);
            }
        }
        m
    }

    pub fn render(&self) -> Table {
        let mut t = Table::new(
            format!(
                "E13 — traffic sweep: p95 response vs offered rate (batch {}, \
                 deadline {} ms, SLO {} ms)",
                TRAFFIC_MAX_BATCH, TRAFFIC_WAIT_MS, TRAFFIC_SLO_MS
            ),
            &[
                "Dataset",
                "Rate (req/s)",
                "x sat",
                "Cent p95",
                "Semi p95",
                "Dec p95",
                "Cent util",
                "Winner",
            ],
        );
        for r in &self.rows {
            for (ri, &rel) in TRAFFIC_REL_RATES.iter().enumerate() {
                let c = r.point(ri, "centralized");
                let s = r.point(ri, "semi");
                let dd = r.point(ri, "decentralized");
                let winner = if s.p95_s < c.p95_s && s.p95_s < dd.p95_s {
                    "semi"
                } else if c.p95_s < dd.p95_s {
                    "centralized"
                } else {
                    "decentralized"
                };
                t.row(&[
                    r.dataset.clone(),
                    format!("{:.0}", c.rate_per_s),
                    format!("{rel:.2}"),
                    Time::s(c.p95_s).to_string(),
                    Time::s(s.p95_s).to_string(),
                    Time::s(dd.p95_s).to_string(),
                    pct(c.utilization),
                    winner.into(),
                ]);
            }
        }
        t
    }

    /// One line per dataset: the crossover finding.
    pub fn summary(&self) -> String {
        self.rows
            .iter()
            .map(|r| match r.crossover_per_s {
                Some(x) => format!(
                    "{}: semi p95 overtakes centralized at ~{:.0} req/s \
                     ({:.2}x leader saturation)",
                    r.dataset,
                    x,
                    x / r.sat_rate_per_s
                ),
                None => format!("{}: centralized p95 wins at every swept rate", r.dataset),
            })
            .collect::<Vec<_>>()
            .join("\n")
    }

    /// The `BENCH_traffic.json` artifact (byte-identical across thread
    /// counts and per seed — asserted in tests).
    pub fn to_json(&self) -> String {
        let num = |v: f64| format!("{v:.6e}");
        let mut rows = Vec::with_capacity(self.rows.len());
        for r in &self.rows {
            let mut pts = Vec::with_capacity(r.points.len());
            for p in &r.points {
                pts.push(format!(
                    "        {{\"setting\": \"{}\", \"rel_rate\": {}, \"rate_per_s\": {}, \
                     \"queue_rate_per_s\": {}, \"servers_total\": {}, \"offered\": {}, \
                     \"utilization\": {}, \"mean_wait_s\": {}, \"mean_batch\": {}, \
                     \"max_queue_depth\": {}, \"mean_s\": {}, \"p50_s\": {}, \
                     \"p95_s\": {}, \"p99_s\": {}, \"slo_attainment\": {}, \
                     \"littles_gap\": {}}}",
                    p.setting,
                    num(p.rel_rate),
                    num(p.rate_per_s),
                    num(p.queue_rate_per_s),
                    p.servers_total,
                    p.offered,
                    num(p.utilization),
                    num(p.mean_wait_s),
                    num(p.mean_batch),
                    p.max_queue_depth,
                    num(p.mean_s),
                    num(p.p50_s),
                    num(p.p95_s),
                    num(p.p99_s),
                    num(p.slo_attainment),
                    num(p.littles_gap),
                ));
            }
            let crossover = match r.crossover_per_s {
                Some(x) => num(x),
                None => "null".into(),
            };
            rows.push(format!(
                "    {{\"dataset\": \"{}\", \"nodes\": {}, \"cluster_size\": {}, \
                 \"clusters\": {}, \"intra_fraction\": {}, \"sat_rate_per_s\": {}, \
                 \"crossover_per_s\": {}, \"points\": [\n{}\n    ]}}",
                r.dataset,
                r.nodes,
                r.cluster_size,
                r.clusters,
                num(r.intra_fraction),
                num(r.sat_rate_per_s),
                crossover,
                pts.join(",\n"),
            ));
        }
        let crossovers: Vec<String> = self
            .rows
            .iter()
            .filter_map(|r| {
                r.crossover_per_s
                    .map(|x| format!("{{\"dataset\": \"{}\", \"rate_per_s\": {}}}", r.dataset, num(x)))
            })
            .collect();
        format!(
            "{{\n  \"experiment\": \"traffic_sweep\",\n  \"config\": {{\
             \"materialize_cap\": {}, \"requests\": {}, \"max_batch\": {}, \
             \"deadline_ms\": {}, \"slo_ms\": {}, \"rel_rates\": [{}]}},\n  \
             \"summary\": {{\"max_littles_gap\": {}, \"crossovers\": [{}]}},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            self.materialize_cap,
            self.requests,
            TRAFFIC_MAX_BATCH,
            num(TRAFFIC_WAIT_MS),
            num(TRAFFIC_SLO_MS),
            TRAFFIC_REL_RATES.map(num).join(", "),
            num(self.max_littles_gap()),
            crossovers.join(", "),
            rows.join(",\n"),
        )
    }
}

/// E14 crash windows expected per representative-queue run (the swept
/// failure rate is this count divided by the run's horizon, so every
/// point sees the same expected outage load regardless of its rate).
pub const FAULT_EXPECTED_OUTAGES: f64 = 3.0;
/// E14 heterogeneous fleet: fraction of the fleet in the slow class.
pub const FAULT_SLOW_SHARE: f64 = 0.25;
/// Speed multiplier of the slow class (service times scale by
/// `1 / speed`).
pub const FAULT_SLOW_SPEED: f64 = 0.5;
/// Degraded-mode service factor while halo replicas (`r >= 2`) keep a
/// crashed device's rows servable.
pub const FAULT_DEGRADED_FACTOR: f64 = 2.0;
/// One f32 feature row (`64 × 4` bytes) — the unit the failover bill
/// re-uploads through the double-buffer barrier.
pub const FAULT_ROW_BYTES: usize = 256;
/// E14 scenario grid: `(name, crashes injected, heterogeneous fleet)`.
/// `faulted_r2` replays `faulted_r1`'s exact crash windows but serves
/// through halo replicas at [`FAULT_DEGRADED_FACTOR`] instead of going
/// dark (the centralized leader has no replica site, so it still takes
/// full outages there).
pub const FAULT_SCENARIOS: [(&str, bool, bool); 4] = [
    ("baseline", false, false),
    ("hetero", false, true),
    ("faulted_r1", true, false),
    ("faulted_r2", true, false),
];

/// One (rate, setting) point of an E14 scenario.  Pure function of the
/// point's seed and config — the parallel byte-identical contract.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultPoint {
    pub setting: &'static str,
    pub rel_rate: f64,
    pub rate_per_s: f64,
    pub offered: usize,
    pub p50_s: f64,
    pub p95_s: f64,
    pub p99_s: f64,
    pub slo_attainment: f64,
    /// `1 − downtime / capacity` of the simulated queues.
    pub availability: f64,
    pub downtime_s: f64,
    /// Crash windows that executed during the run.
    pub fault_windows: usize,
    /// Mean time to recover: `downtime / windows` (0 when no window).
    pub mttr_s: f64,
    pub littles_gap: f64,
}

/// One scenario of one dataset: the full rate × setting grid.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultScenarioRow {
    pub scenario: &'static str,
    /// Expected crash windows per run (0 in fault-free scenarios).
    pub expected_outages: f64,
    /// Slow-class share of the fleet (0 in homogeneous scenarios).
    pub slow_share: f64,
    pub points: Vec<FaultPoint>,
    /// First swept rate where semi p95 beats centralized p95.
    pub crossover_per_s: Option<f64>,
}

impl FaultScenarioRow {
    /// The point for (`rel_rate` index, setting name).
    pub fn point(&self, rel_idx: usize, setting: &str) -> &FaultPoint {
        self.points
            .iter()
            .find(|p| p.setting == setting && p.rel_rate == TRAFFIC_REL_RATES[rel_idx])
            .expect("sweep emits every (rate, setting) point")
    }
}

/// One dataset row of the E14 sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct FaultRow {
    pub dataset: String,
    pub nodes: usize,
    pub cluster_size: usize,
    pub sat_rate_per_s: f64,
    /// Failover bill per setting (seconds): centralized, semi,
    /// decentralized — the fixed outage each crash window charges.
    pub failover_s: [f64; 3],
    pub scenarios: Vec<FaultScenarioRow>,
}

impl FaultRow {
    pub fn scenario(&self, name: &str) -> &FaultScenarioRow {
        self.scenarios
            .iter()
            .find(|s| s.scenario == name)
            .expect("sweep emits every scenario")
    }
}

/// The E14 headline numbers (asserted in tests, reported in the JSON
/// summary).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultHeadline {
    /// Σ over datasets × rates of centralized p95 inflation under
    /// failures (`faulted_r1 − baseline`, seconds).
    pub cent_inflation_s: f64,
    /// Same sum for the semi overlay — failures hurt the single leader
    /// more than the head fleet, which is what shifts the crossover.
    pub semi_inflation_s: f64,
    /// Σ of semi p95 inflation from fleet heterogeneity alone.
    pub hetero_semi_inflation_s: f64,
    /// Datasets whose semi-beats-centralized crossover moved to a
    /// strictly lower swept rate (or newly appeared) under failures.
    pub crossovers_shifted: usize,
    /// Mean SLO attainment over semi + decentralized faulted points.
    pub slo_r1: f64,
    pub slo_r2: f64,
    /// Mean availability over the same points (r2 replicas never go
    /// dark, so this is exactly 1.0 for r2).
    pub availability_r1: f64,
    pub availability_r2: f64,
    /// Σ of semi + decentralized p95 at the top swept rate (overload),
    /// where lost capacity hurts the most.
    pub overload_r1_s: f64,
    pub overload_r2_s: f64,
}

/// E14 — fault injection and fleet heterogeneity over the E13 traffic
/// grid: every (dataset, rate, setting) point re-runs under the
/// [`FAULT_SCENARIOS`] — fault-free baseline, heterogeneous fleet,
/// crash-with-outage (`r = 1`: a crashed device's rows are dark for
/// the whole failover bill) and crash-with-replicas (`r = 2`: halo
/// replicas serve at [`FAULT_DEGRADED_FACTOR`] while the device
/// recovers).  The failover bill is priced by the deployment's own
/// links ([`FailoverCostModel::from_net`]) and charged as downtime.
/// Emits `BENCH_faults.json`.
///
/// Seeds deliberately omit the scenario and setting indices (common
/// random numbers): every scenario replays the same arrival and
/// fault-window draws, so scenario deltas are attributable to the
/// injected faults, not the seeds.  Rows are computed via
/// `par::par_try_map`; output is byte-identical to the sequential run
/// (asserted in tests).
pub struct FaultSweep {
    pub rows: Vec<FaultRow>,
    pub materialize_cap: usize,
    pub requests: usize,
}

impl FaultSweep {
    pub fn run(materialize_cap: usize, requests: usize) -> Result<FaultSweep> {
        FaultSweep::run_with_threads(materialize_cap, requests, par::available_threads())
    }

    /// [`Self::run`] with an explicit worker count (1 = sequential).
    pub fn run_with_threads(
        materialize_cap: usize,
        requests: usize,
        threads: usize,
    ) -> Result<FaultSweep> {
        if requests == 0 {
            return Err(crate::error::Error::Sim("fault sweep needs requests > 0".into()));
        }
        let all = datasets::all();
        let targets: Vec<(usize, DatasetStats)> = all.into_iter().enumerate().collect();
        let rows = par::par_try_map(&targets, threads, |(di, d)| {
            FaultSweep::row(*di, d, materialize_cap, requests)
        })?;
        Ok(FaultSweep { rows, materialize_cap, requests })
    }

    fn row(di: usize, d: &DatasetStats, cap: usize, requests: usize) -> Result<FaultRow> {
        let model = NetModel::fig8(d)?;
        let topo = Topology { nodes: d.nodes, cluster_size: d.avg_cs };
        let sample = d.materialize(cap, 42)?;
        let cs_sample = d.avg_cs.clamp(1, sample.num_nodes());
        let clustering = fixed_size(sample.num_nodes(), cs_sample)?;
        let intra = clustering.intra_edge_fraction(&sample);
        let clustered = LatencyProvider::Clustered { intra_fraction: intra };

        let mut shapes = Vec::with_capacity(3);
        for kind in
            [SettingKind::Centralized, SettingKind::Semi, SettingKind::Decentralized]
        {
            let (queues, service) = deployment_shape(kind, clustered, &model, topo)?;
            shapes.push((kind.name(), queues, service));
        }
        let sat = shapes[0].2.saturation_rate(TRAFFIC_MAX_BATCH);
        let policy = BatchPolicy::Deadline {
            max: TRAFFIC_MAX_BATCH,
            max_wait: Time::ms(TRAFFIC_WAIT_MS),
        };

        // The failover bill per setting, priced by the model's own
        // links: the sweep cannot invent recoveries cheaper than the
        // network it already charges for serving.
        let costs = FailoverCostModel::from_net(&model, FAULT_ROW_BYTES);
        let recovery = [
            costs.centralized(sample.num_nodes()).total(),
            costs.semi(cs_sample).total(),
            costs.decentralized().total(),
        ];

        let homog = FleetMix::homogeneous();
        let mixed = FleetMix::new(vec![
            DeviceClass { name: "fast", speed: 1.0, share: 1.0 - FAULT_SLOW_SHARE },
            DeviceClass { name: "slow", speed: FAULT_SLOW_SPEED, share: FAULT_SLOW_SHARE },
        ])?;

        let mut scenarios = Vec::with_capacity(FAULT_SCENARIOS.len());
        for &(name, crashes, hetero) in FAULT_SCENARIOS.iter() {
            let mut points = Vec::with_capacity(TRAFFIC_REL_RATES.len() * shapes.len());
            for (ri, &rel) in TRAFFIC_REL_RATES.iter().enumerate() {
                let rate = rel * sat;
                for (si, &(setting, queues, service)) in shapes.iter().enumerate() {
                    let queue_rate = queues.per_queue_rate(rate);
                    let horizon_s = requests as f64 / queue_rate;
                    // Common random numbers: no scenario / setting term.
                    let seed = 0xE14_000 + (di as u64) * 64 + (ri as u64) * 8;
                    let cfg = if crashes {
                        // The single leader has no replica site, so the
                        // r = 2 scenario still goes dark centrally.
                        let impact = if name == "faulted_r2" && si > 0 {
                            CrashImpact::Degraded { factor: FAULT_DEGRADED_FACTOR }
                        } else {
                            CrashImpact::Outage
                        };
                        FaultConfig::crashes(
                            FAULT_EXPECTED_OUTAGES / horizon_s,
                            Outage::Fixed(recovery[si]),
                            impact,
                        )
                    } else {
                        FaultConfig::none()
                    };
                    // A 1-queue shape cannot host a 2-class fleet.
                    let hetero_ok = hetero && queues.servers() >= 2;
                    let mix = if hetero_ok { &mixed } else { &homog };
                    let r = open_loop_mix(
                        mix,
                        queues,
                        &service,
                        policy,
                        rate,
                        requests,
                        d.nodes,
                        seed,
                        &cfg,
                        &Obs::disabled(),
                    )?;
                    points.push(FaultPoint {
                        setting,
                        rel_rate: rel,
                        rate_per_s: rate,
                        offered: r.offered(),
                        p50_s: r.p50().as_s(),
                        p95_s: r.p95().as_s(),
                        p99_s: r.p99().as_s(),
                        slo_attainment: r.slo_attainment(Time::ms(TRAFFIC_SLO_MS)),
                        availability: r.availability(),
                        downtime_s: r.downtime().as_s(),
                        fault_windows: r.fault_windows(),
                        mttr_s: r.mttr().as_s(),
                        littles_gap: r.max_littles_gap(),
                    });
                }
            }
            let crossover_per_s = TRAFFIC_REL_RATES.iter().find_map(|&rel| {
                let p95_at = |s: &str| {
                    points
                        .iter()
                        .find(|p| p.setting == s && p.rel_rate == rel)
                        .expect("sweep emits every (rate, setting) point")
                        .p95_s
                };
                (p95_at("semi") < p95_at("centralized")).then_some(rel * sat)
            });
            scenarios.push(FaultScenarioRow {
                scenario: name,
                expected_outages: if crashes { FAULT_EXPECTED_OUTAGES } else { 0.0 },
                slow_share: if hetero { FAULT_SLOW_SHARE } else { 0.0 },
                points,
                crossover_per_s,
            });
        }
        Ok(FaultRow {
            dataset: d.name.to_string(),
            nodes: d.nodes,
            cluster_size: d.avg_cs,
            sat_rate_per_s: sat,
            failover_s: [recovery[0].as_s(), recovery[1].as_s(), recovery[2].as_s()],
            scenarios,
        })
    }

    /// The E14 headline aggregates (docs on [`FaultHeadline`]).
    pub fn headline(&self) -> FaultHeadline {
        let mut h = FaultHeadline {
            cent_inflation_s: 0.0,
            semi_inflation_s: 0.0,
            hetero_semi_inflation_s: 0.0,
            crossovers_shifted: 0,
            slo_r1: 0.0,
            slo_r2: 0.0,
            availability_r1: 0.0,
            availability_r2: 0.0,
            overload_r1_s: 0.0,
            overload_r2_s: 0.0,
        };
        let mut n_slo = 0usize;
        let top = TRAFFIC_REL_RATES.len() - 1;
        for r in &self.rows {
            let base = r.scenario("baseline");
            let het = r.scenario("hetero");
            let r1 = r.scenario("faulted_r1");
            let r2 = r.scenario("faulted_r2");
            for ri in 0..TRAFFIC_REL_RATES.len() {
                h.cent_inflation_s +=
                    r1.point(ri, "centralized").p95_s - base.point(ri, "centralized").p95_s;
                h.semi_inflation_s += r1.point(ri, "semi").p95_s - base.point(ri, "semi").p95_s;
                h.hetero_semi_inflation_s +=
                    het.point(ri, "semi").p95_s - base.point(ri, "semi").p95_s;
                for s in ["semi", "decentralized"] {
                    h.slo_r1 += r1.point(ri, s).slo_attainment;
                    h.slo_r2 += r2.point(ri, s).slo_attainment;
                    h.availability_r1 += r1.point(ri, s).availability;
                    h.availability_r2 += r2.point(ri, s).availability;
                    n_slo += 1;
                }
            }
            for s in ["semi", "decentralized"] {
                h.overload_r1_s += r1.point(top, s).p95_s;
                h.overload_r2_s += r2.point(top, s).p95_s;
            }
            let x1 = r1.crossover_per_s.unwrap_or(f64::INFINITY);
            let x0 = base.crossover_per_s.unwrap_or(f64::INFINITY);
            if x1 < x0 {
                h.crossovers_shifted += 1;
            }
        }
        let n = n_slo.max(1) as f64;
        h.slo_r1 /= n;
        h.slo_r2 /= n;
        h.availability_r1 /= n;
        h.availability_r2 /= n;
        h
    }

    /// Worst Little's-law residual across every point of every scenario.
    pub fn max_littles_gap(&self) -> f64 {
        self.rows
            .iter()
            .flat_map(|r| r.scenarios.iter())
            .flat_map(|s| s.points.iter().map(|p| p.littles_gap))
            .fold(0.0, f64::max)
    }

    /// Post-hoc metrics view — the `.metrics.json` sidecar the CLI
    /// writes next to `BENCH_faults.json`.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        let m = MetricsRegistry::new();
        let h = self.headline();
        m.inc("faults.datasets", self.rows.len() as u64);
        m.set_gauge("faults.max_littles_gap", self.max_littles_gap());
        m.set_gauge("faults.cent_inflation_s", h.cent_inflation_s);
        m.set_gauge("faults.semi_inflation_s", h.semi_inflation_s);
        m.set_gauge("faults.slo_r1", h.slo_r1);
        m.set_gauge("faults.slo_r2", h.slo_r2);
        m.set_gauge("faults.availability_r1", h.availability_r1);
        m.set_gauge("faults.availability_r2", h.availability_r2);
        m.inc("faults.crossovers_shifted", h.crossovers_shifted as u64);
        for r in &self.rows {
            for s in &r.scenarios {
                for p in &s.points {
                    m.inc("faults.points", 1);
                    m.inc("faults.windows", p.fault_windows as u64);
                    m.observe("faults.downtime_s", p.downtime_s);
                    m.observe("faults.p95_s", p.p95_s);
                }
            }
        }
        m
    }

    pub fn render(&self) -> Table {
        let mut t = Table::new(
            format!(
                "E14 — fault sweep: p95 / availability vs offered rate \
                 ({} expected outages, slow share {}, SLO {} ms)",
                FAULT_EXPECTED_OUTAGES, FAULT_SLOW_SHARE, TRAFFIC_SLO_MS
            ),
            &[
                "Dataset",
                "Scenario",
                "x sat",
                "Cent p95",
                "Semi p95",
                "Dec p95",
                "Semi SLO",
                "Semi avail",
            ],
        );
        for r in &self.rows {
            for s in &r.scenarios {
                for (ri, &rel) in TRAFFIC_REL_RATES.iter().enumerate() {
                    let c = s.point(ri, "centralized");
                    let sm = s.point(ri, "semi");
                    let dd = s.point(ri, "decentralized");
                    t.row(&[
                        r.dataset.clone(),
                        s.scenario.into(),
                        format!("{rel:.2}"),
                        Time::s(c.p95_s).to_string(),
                        Time::s(sm.p95_s).to_string(),
                        Time::s(dd.p95_s).to_string(),
                        pct(sm.slo_attainment),
                        pct(sm.availability),
                    ]);
                }
            }
        }
        t
    }

    /// One line per dataset plus the headline aggregates.
    pub fn summary(&self) -> String {
        let mut lines: Vec<String> = self
            .rows
            .iter()
            .map(|r| {
                let fmt = |x: Option<f64>| match x {
                    Some(v) => format!("{v:.0} req/s"),
                    None => "never".into(),
                };
                format!(
                    "{}: semi overtakes centralized at {} fault-free vs {} under \
                     failures (failover bill: cent {}, semi {})",
                    r.dataset,
                    fmt(r.scenario("baseline").crossover_per_s),
                    fmt(r.scenario("faulted_r1").crossover_per_s),
                    Time::s(r.failover_s[0]),
                    Time::s(r.failover_s[1]),
                )
            })
            .collect();
        let h = self.headline();
        lines.push(format!(
            "replication: r=2 SLO attainment {} vs r=1 {} at the same failure \
             times (availability {} vs {})",
            pct(h.slo_r2),
            pct(h.slo_r1),
            pct(h.availability_r2),
            pct(h.availability_r1),
        ));
        lines.join("\n")
    }

    /// The `BENCH_faults.json` artifact (byte-identical across thread
    /// counts and per seed — asserted in tests).
    pub fn to_json(&self) -> String {
        let num = |v: f64| format!("{v:.6e}");
        let h = self.headline();
        let mut rows = Vec::with_capacity(self.rows.len());
        for r in &self.rows {
            let mut scs = Vec::with_capacity(r.scenarios.len());
            for s in &r.scenarios {
                let mut pts = Vec::with_capacity(s.points.len());
                for p in &s.points {
                    pts.push(format!(
                        "          {{\"setting\": \"{}\", \"rel_rate\": {}, \
                         \"rate_per_s\": {}, \"offered\": {}, \"p50_s\": {}, \
                         \"p95_s\": {}, \"p99_s\": {}, \"slo_attainment\": {}, \
                         \"availability\": {}, \"downtime_s\": {}, \
                         \"fault_windows\": {}, \"mttr_s\": {}, \"littles_gap\": {}}}",
                        p.setting,
                        num(p.rel_rate),
                        num(p.rate_per_s),
                        p.offered,
                        num(p.p50_s),
                        num(p.p95_s),
                        num(p.p99_s),
                        num(p.slo_attainment),
                        num(p.availability),
                        num(p.downtime_s),
                        p.fault_windows,
                        num(p.mttr_s),
                        num(p.littles_gap),
                    ));
                }
                let crossover = match s.crossover_per_s {
                    Some(x) => num(x),
                    None => "null".into(),
                };
                scs.push(format!(
                    "      {{\"scenario\": \"{}\", \"expected_outages\": {}, \
                     \"slow_share\": {}, \"crossover_per_s\": {}, \"points\": [\n{}\n      ]}}",
                    s.scenario,
                    num(s.expected_outages),
                    num(s.slow_share),
                    crossover,
                    pts.join(",\n"),
                ));
            }
            rows.push(format!(
                "    {{\"dataset\": \"{}\", \"nodes\": {}, \"cluster_size\": {}, \
                 \"sat_rate_per_s\": {}, \"failover_s\": [{}, {}, {}], \
                 \"scenarios\": [\n{}\n    ]}}",
                r.dataset,
                r.nodes,
                r.cluster_size,
                num(r.sat_rate_per_s),
                num(r.failover_s[0]),
                num(r.failover_s[1]),
                num(r.failover_s[2]),
                scs.join(",\n"),
            ));
        }
        format!(
            "{{\n  \"experiment\": \"fault_sweep\",\n  \"config\": {{\
             \"materialize_cap\": {}, \"requests\": {}, \"expected_outages\": {}, \
             \"slow_share\": {}, \"slow_speed\": {}, \"degraded_factor\": {}, \
             \"row_bytes\": {}, \"slo_ms\": {}, \"rel_rates\": [{}]}},\n  \
             \"summary\": {{\"cent_inflation_s\": {}, \"semi_inflation_s\": {}, \
             \"hetero_semi_inflation_s\": {}, \"crossovers_shifted\": {}, \
             \"slo_r1\": {}, \"slo_r2\": {}, \"availability_r1\": {}, \
             \"availability_r2\": {}, \"max_littles_gap\": {}}},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            self.materialize_cap,
            self.requests,
            num(FAULT_EXPECTED_OUTAGES),
            num(FAULT_SLOW_SHARE),
            num(FAULT_SLOW_SPEED),
            num(FAULT_DEGRADED_FACTOR),
            FAULT_ROW_BYTES,
            num(TRAFFIC_SLO_MS),
            TRAFFIC_REL_RATES.map(num).join(", "),
            num(h.cent_inflation_s),
            num(h.semi_inflation_s),
            num(h.hetero_semi_inflation_s),
            h.crossovers_shifted,
            num(h.slo_r1),
            num(h.slo_r2),
            num(h.availability_r1),
            num(h.availability_r2),
            num(self.max_littles_gap()),
            rows.join(",\n"),
        )
    }
}

/// E15 controller batch cap — smaller than E13's 64 so the windowed
/// stats see fresh completions instead of deep batch pipelines.
pub const CTRL_MAX_BATCH: usize = 16;
/// Diurnal day: mean offered rate relative to leader saturation.
pub const CTRL_DIURNAL_REL: f64 = 0.8;
/// Diurnal relative swing (peak = mean · (1 + swing)).
pub const CTRL_DIURNAL_SWING: f64 = 0.8;
/// Flash-crowd background rate relative to leader saturation.
pub const CTRL_FLASH_REL: f64 = 0.6;
/// Flash-crowd rate multiplier during the spike window.
pub const CTRL_FLASH_BOOST: f64 = 5.0;
/// Flash spike start / width as fractions of the horizon.
pub const CTRL_FLASH_AT: f64 = 0.4;
pub const CTRL_FLASH_WIDTH: f64 = 0.2;
/// Link-degradation factor / window of the faulted E15 scenario (the
/// only fault kind that composes with a deployment switch).
pub const CTRL_LINK_FACTOR: f64 = 2.0;
pub const CTRL_LINK_FROM: f64 = 0.55;
pub const CTRL_LINK_UNTIL: f64 = 0.70;
/// A rung joins the capacity ladder only with at least this much
/// aggregate saturation throughput over the rung below, so every
/// escalation buys real capacity.
pub const CTRL_LADDER_GAIN: f64 = 1.5;
/// E15 scenario grid (each runs the adaptive controller against every
/// static rung on the *same* arrival draw — common random numbers).
pub const CTRL_SCENARIOS: [&str; 3] = ["diurnal", "flash", "linkfault"];

/// Response statistics of one E15 run (adaptive or static), all against
/// the row's serving SLO.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CtrlRunStat {
    pub p95_s: f64,
    pub mean_s: f64,
    pub slo_attainment: f64,
    pub utilization: f64,
    pub littles_gap: f64,
}

fn ctrl_stat(r: &TrafficReport, slo: Time) -> CtrlRunStat {
    CtrlRunStat {
        p95_s: r.latency.p95().as_s(),
        mean_s: r.latency.mean().as_s(),
        slo_attainment: r.slo_attainment(slo),
        utilization: r.utilization,
        littles_gap: r.littles_law_gap(),
    }
}

/// The E15 capacity ladder and derived control constants of one
/// dataset — shared by the sweep, the `ima-gnn control` single-run
/// mode and the integration tests, so they all switch over the exact
/// same rungs and thresholds.
#[derive(Debug, Clone)]
pub struct ControlSetup {
    /// Cheapest-first, [`CTRL_LADDER_GAIN`]-gated deployment rungs.
    pub ladder: Vec<CtrlConfig>,
    /// The serving SLO (docs on [`ControllerRow::slo_s`]).
    pub slo: Time,
    /// Escalation queue-depth threshold.
    pub depth_hi: f64,
    /// Leader-rung aggregate saturation — the rate anchor.
    pub sat_rate_per_s: f64,
    pub sample_nodes: usize,
    pub cluster_size: usize,
}

/// Build the [`ControlSetup`] for `d` at sample cap `cap`: shape the
/// three deployment settings at sample scale, gate them into a
/// capacity ladder, and price each rung's switch-in bill with
/// [`FailoverCostModel::from_net`] (ShardPlan rebuild + FeatureStore
/// re-upload through the double-buffer barrier).
pub fn control_setup(d: &DatasetStats, cap: usize) -> Result<ControlSetup> {
    let model = NetModel::fig8(d)?;
    let sample = d.materialize(cap, 42)?;
    let n = sample.num_nodes();
    let cs = d.avg_cs.clamp(1, n);
    let clustering = fixed_size(n, cs)?;
    let intra = clustering.intra_edge_fraction(&sample);
    let clustered = LatencyProvider::Clustered { intra_fraction: intra };
    // Sample-scale topology: every rung serves the *same* request
    // stream, so the devices rung must be one queue per sampled
    // device, not per full-fleet device.
    let topo = Topology { nodes: n, cluster_size: cs };

    let costs = FailoverCostModel::from_net(&model, FAULT_ROW_BYTES);
    let mut ladder: Vec<CtrlConfig> = Vec::new();
    for kind in [SettingKind::Centralized, SettingKind::Semi, SettingKind::Decentralized] {
        let (queues, service) = deployment_shape(kind, clustered, &model, topo)?;
        let policy = BatchPolicy::Deadline {
            max: CTRL_MAX_BATCH,
            max_wait: service.service(1) * 0.25,
        };
        let (point, switch_cost) = match kind {
            SettingKind::Centralized => {
                (OperatingPoint::centralized(), costs.centralized(n).total())
            }
            SettingKind::Semi => (
                OperatingPoint::semi(cs, 1.0, Partitioner::FixedSize),
                costs.semi(cs).total(),
            ),
            SettingKind::Decentralized => (
                OperatingPoint::decentralized(cs, Partitioner::FixedSize),
                costs.decentralized().total(),
            ),
        };
        let cfg = CtrlConfig { point, queues, service, policy, switch_cost };
        let admit = match ladder.last() {
            None => true,
            Some(prev) => {
                cfg.saturation_aggregate() >= CTRL_LADDER_GAIN * prev.saturation_aggregate()
            }
        };
        if admit {
            ladder.push(cfg);
        }
    }
    let sat_c = ladder[0].saturation_aggregate();
    let s_c1 = ladder[0].service.service(1).as_s();
    let s_next1 = match ladder.get(1) {
        Some(c) => c.service.service(1).as_s(),
        None => s_c1 * 4.0,
    };
    // Geometric blend between the leader's and the next rung's
    // single-request service: the unloaded leader meets it, every
    // capacity rung misses it on latency alone — which is what makes
    // staying cheap worth it when the day is quiet.
    let slo = Time::s(s_c1 * (s_next1 / s_c1).powf(0.75));
    let depth_hi = ((slo.as_s() / s_c1 - 1.0) * CTRL_MAX_BATCH as f64).ceil().max(32.0);
    Ok(ControlSetup {
        ladder,
        slo,
        depth_hi,
        sat_rate_per_s: sat_c,
        sample_nodes: n,
        cluster_size: cs,
    })
}

/// One E15 cell (dataset × scenario), prepared for execution: the CRN
/// arrival stream every run of the cell replays, the controller, and
/// the fault plan (only [`FaultKind::LinkDegrade`] — the one fault
/// kind whose semantics survive a deployment switch).
pub struct ControlCell {
    pub arrivals: Vec<Arrival>,
    pub controller: Controller,
    pub plan: FaultPlan,
    pub horizon: Time,
    pub window: Time,
    pub dwell: Time,
}

/// Build one E15 cell.  `scenario` is one of [`CTRL_SCENARIOS`];
/// `nodes` is the *full-scale* fleet the arrival node ids draw from.
pub fn control_cell(
    setup: &ControlSetup,
    scenario: &str,
    nodes: usize,
    requests: usize,
    seed: u64,
) -> Result<ControlCell> {
    let sat_c = setup.sat_rate_per_s;
    let base = match scenario {
        "flash" => CTRL_FLASH_REL * sat_c,
        "diurnal" | "linkfault" => CTRL_DIURNAL_REL * sat_c,
        other => {
            return Err(crate::error::Error::Sim(format!("unknown E15 scenario `{other}`")))
        }
    };
    let horizon = Time::s(requests as f64 / base);
    let process = match scenario {
        "flash" => ArrivalProcess::FlashCrowd {
            base,
            boost: CTRL_FLASH_BOOST,
            at: horizon * CTRL_FLASH_AT,
            width: horizon * CTRL_FLASH_WIDTH,
        },
        _ => ArrivalProcess::Diurnal(DiurnalCurve::new(base, CTRL_DIURNAL_SWING, horizon)?),
    };
    let arrivals = process.generate(horizon, nodes, seed)?;
    let window = Time::s(horizon.as_s() / 48.0);
    let dwell = Time::s(horizon.as_s() / 16.0);
    let hyst = Hysteresis {
        window,
        dwell,
        p95_hi: setup.slo,
        depth_hi: setup.depth_hi,
        min_samples: 8,
        down_fraction: 0.7,
        util_hi: 0.5,
    };
    let controller = Controller::new(setup.ladder.clone(), 0, hyst)?;
    let plan = if scenario == "linkfault" {
        let max_servers = setup.ladder.iter().map(|c| c.queues.servers()).max().unwrap_or(1);
        FaultPlan::from_events(
            vec![FaultEvent {
                at: horizon * CTRL_LINK_FROM,
                until: horizon * CTRL_LINK_UNTIL,
                kind: FaultKind::LinkDegrade { factor: CTRL_LINK_FACTOR },
            }],
            max_servers,
        )?
    } else {
        FaultPlan::none()
    };
    Ok(ControlCell { arrivals, controller, plan, horizon, window, dwell })
}

/// One rung of a row's capacity ladder, as serialized.
#[derive(Debug, Clone, PartialEq)]
pub struct CtrlRungInfo {
    pub label: String,
    pub servers: usize,
    /// Aggregate saturation throughput (req/s).
    pub sat_per_s: f64,
    /// Priced cost of switching *into* this rung (ShardPlan rebuild +
    /// FeatureStore re-upload through the double-buffer barrier).
    pub switch_cost_s: f64,
}

/// One scenario of one dataset: the adaptive run vs every static rung
/// on the same arrivals.
#[derive(Debug, Clone, PartialEq)]
pub struct CtrlScenarioRow {
    pub scenario: &'static str,
    pub horizon_s: f64,
    pub window_s: f64,
    pub dwell_s: f64,
    /// Requests offered (identical for adaptive and statics — CRN).
    pub offered: usize,
    pub adaptive: CtrlRunStat,
    /// Parallel to the row's ladder.
    pub statics: Vec<CtrlRunStat>,
    pub switches: Vec<SwitchRecord>,
    pub switch_downtime_s: f64,
    pub switch_affected: usize,
    pub final_config: usize,
    /// Consecutive switches respected `resume + dwell` (no flapping).
    pub dwell_ok: bool,
}

/// One dataset row of the E15 sweep.
#[derive(Debug, Clone, PartialEq)]
pub struct ControllerRow {
    pub dataset: String,
    /// Full-scale fleet (arrival node ids draw from this range).
    pub nodes: usize,
    /// Capped sample the ladder's queues are shaped at.
    pub sample_nodes: usize,
    pub cluster_size: usize,
    /// Leader-rung aggregate saturation — the rate anchor.
    pub sat_rate_per_s: f64,
    /// The serving SLO: geometric blend of the leader's and the next
    /// rung's single-request service, so the unloaded leader meets it
    /// while every capacity rung misses it on latency alone.
    pub slo_s: f64,
    pub ladder: Vec<CtrlRungInfo>,
    pub scenarios: Vec<CtrlScenarioRow>,
}

impl ControllerRow {
    pub fn scenario(&self, name: &str) -> &CtrlScenarioRow {
        self.scenarios
            .iter()
            .find(|s| s.scenario == name)
            .expect("sweep emits every scenario")
    }
}

/// The E15 headline (asserted in tests, reported in the JSON summary).
#[derive(Debug, Clone, PartialEq)]
pub struct ControllerHeadline {
    /// Datasets where the adaptive controller's full-day attainment
    /// (summed over scenarios) is at least every static rung's.
    pub adaptive_win_datasets: usize,
    /// Every cell: adaptive attainment ≥ best static − the priced
    /// switch overhead (`switch_affected / offered`).
    pub bound_ok: bool,
    /// Every adaptive run respected the min-dwell between switches.
    pub dwell_ok: bool,
    pub total_switches: usize,
    /// Worst per-cell attainment deficit vs the best static.
    pub worst_regret: f64,
    /// Largest per-cell switch overhead (the bound's allowance).
    pub max_switch_overhead: f64,
    pub mean_adaptive_slo: f64,
    pub mean_best_static_slo: f64,
}

/// E15 — closed-loop adaptive runtime control over the E13 traffic
/// engine: per dataset, a capacity ladder of deployment shapes
/// (leader → cluster heads → devices, [`CTRL_LADDER_GAIN`]-gated) is
/// driven through a diurnal day, a flash crowd and a link-degraded day
/// ([`CTRL_SCENARIOS`]).  The [`Controller`] watches windowed p95 /
/// depth / utilization on the sim-time axis and switches rungs through
/// a priced graceful-drain pause ([`FailoverCostModel`] bill); every
/// static rung replays the identical arrivals (common random numbers),
/// so the adaptive-vs-static deltas are attributable to control alone.
/// Emits `BENCH_controller.json`; rows run via `par::par_try_map` and
/// the artifact is byte-identical across thread counts.
pub struct ControllerSweep {
    pub rows: Vec<ControllerRow>,
    pub materialize_cap: usize,
    pub requests: usize,
}

impl ControllerSweep {
    pub fn run(materialize_cap: usize, requests: usize) -> Result<ControllerSweep> {
        ControllerSweep::run_with_threads(materialize_cap, requests, par::available_threads())
    }

    /// [`Self::run`] with an explicit worker count (1 = sequential).
    pub fn run_with_threads(
        materialize_cap: usize,
        requests: usize,
        threads: usize,
    ) -> Result<ControllerSweep> {
        if requests == 0 {
            return Err(crate::error::Error::Sim("controller sweep needs requests > 0".into()));
        }
        let all = datasets::all();
        let targets: Vec<(usize, DatasetStats)> = all.into_iter().enumerate().collect();
        let rows = par::par_try_map(&targets, threads, |(di, d)| {
            ControllerSweep::row(*di, d, materialize_cap, requests)
        })?;
        Ok(ControllerSweep { rows, materialize_cap, requests })
    }

    fn row(di: usize, d: &DatasetStats, cap: usize, requests: usize) -> Result<ControllerRow> {
        let setup = control_setup(d, cap)?;
        let slo = setup.slo;
        let mut scenarios = Vec::with_capacity(CTRL_SCENARIOS.len());
        for (sc, &name) in CTRL_SCENARIOS.iter().enumerate() {
            let seed = 0xE15_000 + (di as u64) * 64 + (sc as u64) * 8;
            let cell = control_cell(&setup, name, d.nodes, requests, seed)?;
            let obs = Obs::disabled();
            let cr = open_loop_controlled(&cell.controller, &cell.arrivals, &cell.plan, &obs)?;
            let adaptive = ctrl_stat(&cr.report, slo);
            // Every static rung replays the same arrivals and the same
            // fault plan (common random numbers) — the only thing that
            // differs from the adaptive run is the fixed shape.
            let mut statics = Vec::with_capacity(setup.ladder.len());
            for cfg in &setup.ladder {
                let r = open_loop_faulted(
                    cfg.queues.servers(),
                    &cfg.service,
                    cfg.policy,
                    &cell.arrivals,
                    &cell.plan,
                    &obs,
                )?;
                statics.push(ctrl_stat(&r, slo));
            }
            let mut dwell_ok = true;
            for w in cr.switches.windows(2) {
                let resume = w[0].at + w[0].cost;
                if w[1].at.as_s() + 1e-12 < (resume + cell.dwell).as_s() {
                    dwell_ok = false;
                }
            }
            scenarios.push(CtrlScenarioRow {
                scenario: name,
                horizon_s: cell.horizon.as_s(),
                window_s: cell.window.as_s(),
                dwell_s: cell.dwell.as_s(),
                offered: cr.report.offered,
                adaptive,
                statics,
                switches: cr.switches,
                switch_downtime_s: cr.switch_downtime.as_s(),
                switch_affected: cr.switch_affected,
                final_config: cr.final_config,
                dwell_ok,
            });
        }
        let ladder_info = setup
            .ladder
            .iter()
            .map(|c| CtrlRungInfo {
                label: c.label(),
                servers: c.queues.servers(),
                sat_per_s: c.saturation_aggregate(),
                switch_cost_s: c.switch_cost.as_s(),
            })
            .collect();
        Ok(ControllerRow {
            dataset: d.name.to_string(),
            nodes: d.nodes,
            sample_nodes: setup.sample_nodes,
            cluster_size: setup.cluster_size,
            sat_rate_per_s: setup.sat_rate_per_s,
            slo_s: slo.as_s(),
            ladder: ladder_info,
            scenarios,
        })
    }

    /// The E15 headline aggregates (docs on [`ControllerHeadline`]).
    pub fn headline(&self) -> ControllerHeadline {
        let mut h = ControllerHeadline {
            adaptive_win_datasets: 0,
            bound_ok: true,
            dwell_ok: true,
            total_switches: 0,
            worst_regret: 0.0,
            max_switch_overhead: 0.0,
            mean_adaptive_slo: 0.0,
            mean_best_static_slo: 0.0,
        };
        let mut cells = 0usize;
        for r in &self.rows {
            let mut adaptive_day = 0.0f64;
            let mut static_day = vec![0.0f64; r.ladder.len()];
            for s in &r.scenarios {
                adaptive_day += s.adaptive.slo_attainment;
                let mut best = 0.0f64;
                for (j, st) in s.statics.iter().enumerate() {
                    static_day[j] += st.slo_attainment;
                    best = best.max(st.slo_attainment);
                }
                let overhead = s.switch_affected as f64 / s.offered.max(1) as f64;
                let regret = best - s.adaptive.slo_attainment;
                h.worst_regret = h.worst_regret.max(regret);
                h.max_switch_overhead = h.max_switch_overhead.max(overhead);
                if regret > overhead + 1e-9 {
                    h.bound_ok = false;
                }
                h.dwell_ok &= s.dwell_ok;
                h.total_switches += s.switches.len();
                h.mean_adaptive_slo += s.adaptive.slo_attainment;
                h.mean_best_static_slo += best;
                cells += 1;
            }
            let best_day = static_day.iter().fold(0.0f64, |a, &b| a.max(b));
            if adaptive_day >= best_day - 1e-9 {
                h.adaptive_win_datasets += 1;
            }
        }
        let c = cells.max(1) as f64;
        h.mean_adaptive_slo /= c;
        h.mean_best_static_slo /= c;
        h
    }

    /// Worst Little's-law residual across every run of every cell.
    pub fn max_littles_gap(&self) -> f64 {
        self.rows
            .iter()
            .flat_map(|r| r.scenarios.iter())
            .flat_map(|s| {
                std::iter::once(s.adaptive.littles_gap)
                    .chain(s.statics.iter().map(|p| p.littles_gap))
            })
            .fold(0.0, f64::max)
    }

    /// Post-hoc metrics view — the `.metrics.json` sidecar the CLI
    /// writes next to `BENCH_controller.json`.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        let m = MetricsRegistry::new();
        let h = self.headline();
        m.inc("controller.datasets", self.rows.len() as u64);
        m.inc("controller.switches", h.total_switches as u64);
        m.inc("controller.win_datasets", h.adaptive_win_datasets as u64);
        m.set_gauge("controller.bound_ok", if h.bound_ok { 1.0 } else { 0.0 });
        m.set_gauge("controller.dwell_ok", if h.dwell_ok { 1.0 } else { 0.0 });
        m.set_gauge("controller.mean_adaptive_slo", h.mean_adaptive_slo);
        m.set_gauge("controller.mean_best_static_slo", h.mean_best_static_slo);
        m.set_gauge("controller.worst_regret", h.worst_regret);
        m.set_gauge("controller.max_switch_overhead", h.max_switch_overhead);
        m.set_gauge("controller.max_littles_gap", self.max_littles_gap());
        for r in &self.rows {
            for s in &r.scenarios {
                m.inc("controller.cells", 1);
                m.inc("controller.switch_affected", s.switch_affected as u64);
                m.observe("controller.switch_downtime_s", s.switch_downtime_s);
                m.observe("controller.adaptive_p95_s", s.adaptive.p95_s);
                m.observe("controller.adaptive_slo", s.adaptive.slo_attainment);
            }
        }
        m
    }

    pub fn render(&self) -> Table {
        let mut t = Table::new(
            format!(
                "E15 — closed-loop control: adaptive vs static rungs (batch {}, \
                 ladder gain {}x)",
                CTRL_MAX_BATCH, CTRL_LADDER_GAIN
            ),
            &[
                "Dataset",
                "Scenario",
                "Adaptive SLO",
                "Best static",
                "Static SLO",
                "Switches",
                "Downtime",
                "Final rung",
            ],
        );
        for r in &self.rows {
            for s in &r.scenarios {
                let (bj, best) = s
                    .statics
                    .iter()
                    .enumerate()
                    .max_by(|a, b| {
                        a.1.slo_attainment
                            .partial_cmp(&b.1.slo_attainment)
                            .expect("attainment is never NaN")
                    })
                    .expect("ladder is non-empty");
                t.row(&[
                    r.dataset.clone(),
                    s.scenario.into(),
                    pct(s.adaptive.slo_attainment),
                    r.ladder[bj].label.clone(),
                    pct(best.slo_attainment),
                    s.switches.len().to_string(),
                    Time::s(s.switch_downtime_s).to_string(),
                    r.ladder[s.final_config].label.clone(),
                ]);
            }
        }
        t
    }

    /// One line per dataset plus the headline verdict.
    pub fn summary(&self) -> String {
        let mut lines: Vec<String> = self
            .rows
            .iter()
            .map(|r| {
                let day: f64 =
                    r.scenarios.iter().map(|s| s.adaptive.slo_attainment).sum();
                let best_day = (0..r.ladder.len())
                    .map(|j| {
                        r.scenarios.iter().map(|s| s.statics[j].slo_attainment).sum::<f64>()
                    })
                    .fold(0.0f64, f64::max)
                    / CTRL_SCENARIOS.len() as f64;
                let switches: usize =
                    r.scenarios.iter().map(|s| s.switches.len()).sum();
                format!(
                    "{}: adaptive {} vs best static {} over the {}-scenario day \
                     ({} switches, {} rungs)",
                    r.dataset,
                    pct(day / CTRL_SCENARIOS.len() as f64),
                    pct(best_day),
                    CTRL_SCENARIOS.len(),
                    switches,
                    r.ladder.len(),
                )
            })
            .collect();
        let h = self.headline();
        lines.push(format!(
            "headline: adaptive wins {} of {} datasets; worst regret {} vs priced \
             switch allowance {}",
            h.adaptive_win_datasets,
            self.rows.len(),
            pct(h.worst_regret),
            pct(h.max_switch_overhead),
        ));
        lines.join("\n")
    }

    /// The `BENCH_controller.json` artifact (byte-identical across
    /// thread counts and per seed — asserted in tests).
    pub fn to_json(&self) -> String {
        let num = |v: f64| format!("{v:.6e}");
        let h = self.headline();
        let mut rows = Vec::with_capacity(self.rows.len());
        for r in &self.rows {
            let ladder: Vec<String> = r
                .ladder
                .iter()
                .map(|g| {
                    format!(
                        "      {{\"label\": \"{}\", \"servers\": {}, \"sat_per_s\": {}, \
                         \"switch_cost_s\": {}}}",
                        g.label,
                        g.servers,
                        num(g.sat_per_s),
                        num(g.switch_cost_s),
                    )
                })
                .collect();
            let mut scs = Vec::with_capacity(r.scenarios.len());
            for s in &r.scenarios {
                let stat = |p: &CtrlRunStat| {
                    format!(
                        "{{\"p95_s\": {}, \"mean_s\": {}, \"slo_attainment\": {}, \
                         \"utilization\": {}, \"littles_gap\": {}}}",
                        num(p.p95_s),
                        num(p.mean_s),
                        num(p.slo_attainment),
                        num(p.utilization),
                        num(p.littles_gap),
                    )
                };
                let statics: Vec<String> = s.statics.iter().map(&stat).collect();
                let switches: Vec<String> = s
                    .switches
                    .iter()
                    .map(|w| {
                        format!(
                            "{{\"at_s\": {}, \"from\": {}, \"to\": {}, \"cost_s\": {}, \
                             \"moved\": {}}}",
                            num(w.at.as_s()),
                            w.from,
                            w.to,
                            num(w.cost.as_s()),
                            w.moved,
                        )
                    })
                    .collect();
                scs.push(format!(
                    "      {{\"scenario\": \"{}\", \"horizon_s\": {}, \"window_s\": {}, \
                     \"dwell_s\": {}, \"offered\": {}, \"switch_downtime_s\": {}, \
                     \"switch_affected\": {}, \"final_config\": {}, \"dwell_ok\": {}, \
                     \"adaptive\": {}, \"statics\": [{}], \"switches\": [{}]}}",
                    s.scenario,
                    num(s.horizon_s),
                    num(s.window_s),
                    num(s.dwell_s),
                    s.offered,
                    num(s.switch_downtime_s),
                    s.switch_affected,
                    s.final_config,
                    s.dwell_ok,
                    stat(&s.adaptive),
                    statics.join(", "),
                    switches.join(", "),
                ));
            }
            rows.push(format!(
                "    {{\"dataset\": \"{}\", \"nodes\": {}, \"sample_nodes\": {}, \
                 \"cluster_size\": {}, \"sat_rate_per_s\": {}, \"slo_s\": {}, \
                 \"ladder\": [\n{}\n    ], \"scenarios\": [\n{}\n    ]}}",
                r.dataset,
                r.nodes,
                r.sample_nodes,
                r.cluster_size,
                num(r.sat_rate_per_s),
                num(r.slo_s),
                ladder.join(",\n"),
                scs.join(",\n"),
            ));
        }
        format!(
            "{{\n  \"experiment\": \"controller_sweep\",\n  \"config\": {{\
             \"materialize_cap\": {}, \"requests\": {}, \"max_batch\": {}, \
             \"ladder_gain\": {}, \"diurnal_rel\": {}, \"diurnal_swing\": {}, \
             \"flash_rel\": {}, \"flash_boost\": {}, \"link_factor\": {}, \
             \"scenarios\": [{}]}},\n  \
             \"summary\": {{\"adaptive_win_datasets\": {}, \"bound_ok\": {}, \
             \"dwell_ok\": {}, \"total_switches\": {}, \"worst_regret\": {}, \
             \"max_switch_overhead\": {}, \"mean_adaptive_slo\": {}, \
             \"mean_best_static_slo\": {}, \"max_littles_gap\": {}}},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            self.materialize_cap,
            self.requests,
            CTRL_MAX_BATCH,
            num(CTRL_LADDER_GAIN),
            num(CTRL_DIURNAL_REL),
            num(CTRL_DIURNAL_SWING),
            num(CTRL_FLASH_REL),
            num(CTRL_FLASH_BOOST),
            num(CTRL_LINK_FACTOR),
            CTRL_SCENARIOS
                .iter()
                .map(|s| format!("\"{s}\""))
                .collect::<Vec<_>>()
                .join(", "),
            h.adaptive_win_datasets,
            h.bound_ok,
            h.dwell_ok,
            h.total_switches,
            num(h.worst_regret),
            num(h.max_switch_overhead),
            num(h.mean_adaptive_slo),
            num(h.mean_best_static_slo),
            num(self.max_littles_gap()),
            rows.join(",\n"),
        )
    }
}

/// E16 scale grid: LiveJournal-shape graphs from warm-up to the
/// million-node headline (`--max-nodes` filters it; CI's quick mode
/// stops at 100 k).
pub const RESIDENCY_GRID: [usize; 3] = [10_000, 100_000, 1_000_000];
/// E16 average out-degree — LiveJournal's Table 2 Avg Cₛ, so the R-MAT
/// graphs match the paper's edge-per-node budget.
pub const RESIDENCY_DEGREE: usize = 9;
/// E16 default byte budget, in decoded shards.  Two shards is the
/// minimum that lets the deterministic next-shard prefetch coexist with
/// the pinned fetch target (DESIGN.md §16).
pub const RESIDENCY_BUDGET_SHARDS: usize = 2;

/// The E16 artifact binding: a wide table (4096 rows) with a narrow
/// feature so million-node graphs shard into hundreds of tables while
/// the per-row work stays cheap enough for debug-mode tests.
pub fn residency_binding() -> GcnLayerBinding {
    GcnLayerBinding {
        artifact: "gcn_layer_b64_s2_f1_h8_t4096".into(),
        batch: 64,
        sample: 2,
        feature: 1,
        hidden: 8,
        table: 4096,
    }
}

/// One scale point of the E16 residency sweep.  Every field except the
/// two wall clocks is a pure function of (nodes, rounds, budget_shards)
/// — the parallel byte-identical contract; the walls are attached only
/// in timed runs.
#[derive(Debug, Clone, PartialEq)]
pub struct ResidencyRow {
    pub nodes: usize,
    pub edges: usize,
    pub shards: usize,
    pub table: usize,
    /// Resident-set byte ceiling the run is held under.
    pub budget_bytes: usize,
    /// High-water mark of decoded bytes — asserted ≤ `budget_bytes`.
    pub peak_bytes: usize,
    /// What an unbounded cache (the seed path) would hold decoded.
    pub unbounded_bytes: usize,
    /// Compact CSR footprint (varint neighbors + offsets + permutations).
    pub graph_encoded_bytes: usize,
    /// Seed CSR footprint the ratio is measured against.
    pub graph_seed_bytes: usize,
    pub compression_ratio: f64,
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    pub prefetch_issued: u64,
    pub prefetch_hits: u64,
    pub hit_rate: f64,
    /// Barrier-time shard encodes (= shards × rounds, replacing the seed
    /// path's `table_builds`).
    pub shard_encodes: u64,
    pub batches_per_round: u64,
    pub rounds: usize,
    /// Wall of the resident (decode-on-fetch) serve loop.
    pub resident_wall_s: Option<f64>,
    /// Wall of the identical loop on the seed (unbounded-cache) engine.
    pub seed_wall_s: Option<f64>,
}

impl ResidencyRow {
    /// Decode overhead headline: resident wall over seed wall (`None`
    /// in untimed determinism runs).
    pub fn decode_overhead(&self) -> Option<f64> {
        match (self.resident_wall_s, self.seed_wall_s) {
            (Some(r), Some(s)) if s > 0.0 => Some(r / s),
            _ => None,
        }
    }
}

/// FNV-1a fold of one little-endian word into the digest `h`.
fn digest_word(h: &mut u64, v: u64) {
    for b in v.to_le_bytes() {
        *h = (*h ^ u64::from(b)).wrapping_mul(0x100_0000_01b3);
    }
}

/// Digest one served batch: the fetched table tensor plus the assembled
/// `x_self` / `nbr_idx` inputs, all via `to_bits` so the comparison is
/// bit-exact, not approximate.
fn digest_batch(h: &mut u64, table: &[f32], b: &ShardBatch) {
    digest_word(h, b.shard as u64);
    for v in table {
        digest_word(h, u64::from(v.to_bits()));
    }
    for v in &b.x_self {
        digest_word(h, u64::from(v.to_bits()));
    }
    for &v in &b.nbr_idx {
        digest_word(h, u64::from(v as u32));
    }
}

/// E16 — million-node residency sweep: LiveJournal-shape R-MAT graphs
/// served through the [`RoundEngine`] with the byte-budgeted
/// [`crate::graph::ResidentSet`] tier enabled, emitting
/// `BENCH_residency.json` (DESIGN.md §16).
///
/// Each scale runs the same upload → barrier → assemble → fetch loop
/// twice — once on the resident engine (ExactI32 quantization, budget =
/// `budget_shards` decoded shards) and once on the seed engine with its
/// unbounded tensor cache — and folds every fetched table and assembled
/// batch into an FNV digest.  The row errors if the digests diverge
/// (the bit-identity contract) or if `peak_bytes` exceeds the budget
/// (the residency ceiling).  Rows are computed via `par::par_try_map`;
/// untimed output is byte-identical across thread counts.
pub struct ResidencySweep {
    pub rows: Vec<ResidencyRow>,
    pub max_nodes: usize,
    pub rounds: usize,
    pub budget_shards: usize,
}

impl ResidencySweep {
    /// Timed sweep over all available cores (the CLI / CI entry point).
    pub fn run(max_nodes: usize, rounds: usize, budget_shards: usize) -> Result<ResidencySweep> {
        ResidencySweep::run_with_threads(
            max_nodes,
            rounds,
            budget_shards,
            par::available_threads(),
            true,
        )
    }

    /// One timed scale point at exactly `nodes` — the CLI's single-run
    /// mode (the sweep grid only carries the standard E16 scales).
    pub fn single(nodes: usize, rounds: usize, budget_shards: usize) -> Result<ResidencyRow> {
        ResidencySweep::row(nodes, rounds, budget_shards, true)
    }

    /// Fully parameterized sweep; `timed = false` drops the wall fields
    /// so the output is reproducible bit-for-bit across thread counts.
    pub fn run_with_threads(
        max_nodes: usize,
        rounds: usize,
        budget_shards: usize,
        threads: usize,
        timed: bool,
    ) -> Result<ResidencySweep> {
        let mut scales: Vec<usize> =
            RESIDENCY_GRID.iter().copied().filter(|&n| n <= max_nodes).collect();
        if scales.is_empty() {
            scales.push(max_nodes);
        }
        let rows = par::par_try_map(&scales, threads, |&n| {
            ResidencySweep::row(n, rounds, budget_shards, timed)
        })?;
        Ok(ResidencySweep { rows, max_nodes, rounds, budget_shards })
    }

    fn row(
        nodes: usize,
        rounds: usize,
        budget_shards: usize,
        timed: bool,
    ) -> Result<ResidencyRow> {
        let g = generate::rmat(
            nodes,
            nodes * RESIDENCY_DEGREE,
            &generate::RmatParams::default(),
            0xE16,
        )?;
        let compact = CompactCsr::from_csr(&g)?;
        let binding = residency_binding();
        let (feature, hidden, table) = (binding.feature, binding.hidden, binding.table);
        let plan = ShardPlan::build(&g, &binding.sampler(), table)?;
        let weights = vec![0.01; feature * hidden];
        let mut res = RoundEngine::new(binding, plan.clone(), weights.clone())?;
        let shard_bytes = table * feature * std::mem::size_of::<f32>();
        let budget = budget_shards.max(1) * shard_bytes;
        res.enable_residency(FeatureQuant::ExactI32, budget)?;
        let mut seed = RoundEngine::new(residency_binding(), plan, weights)?;
        let n = g.num_nodes();
        let all: Vec<usize> = (0..n).collect();
        // Integer-valued features, drawn OUTSIDE the timed windows: the
        // ExactI32 codec is bit-exact on these (DESIGN.md §16), which is
        // what the digest comparison asserts; the walls measure the
        // engines, not the test RNG.
        let round_features: Vec<Vec<f32>> = (0..rounds)
            .map(|round| {
                let mut rng = Rng::new(0xE16C + round as u64);
                (0..n * feature).map(|_| rng.index(512) as f32).collect()
            })
            .collect();
        let drive = |engine: &mut RoundEngine| -> Result<(u64, u64, f64)> {
            let mut digest = 0xcbf2_9ce4_8422_2325u64;
            let mut batches_per_round = 0u64;
            let t0 = std::time::Instant::now();
            for feats in &round_features {
                for node in 0..n {
                    engine.upload(node, &feats[node * feature..(node + 1) * feature])?;
                }
                engine.try_end_round()?;
                let batches = engine.assemble(&all)?;
                batches_per_round = batches.len() as u64;
                // Batches come back shard-ascending, so the fetch scan is
                // sequential in plan order — the pattern the next-shard
                // prefetch turns into hits.
                for b in &batches {
                    let t = engine.fetch_table(b.shard)?;
                    digest_batch(&mut digest, t.as_f32()?, b);
                }
            }
            Ok((digest, batches_per_round, t0.elapsed().as_secs_f64()))
        };
        let (res_digest, batches_per_round, res_wall) = drive(&mut res)?;
        let (seed_digest, _, seed_wall) = drive(&mut seed)?;
        if res_digest != seed_digest {
            return Err(Error::Graph(format!(
                "residency serve diverged from the seed path at {nodes} nodes"
            )));
        }
        let tier = res.resident().expect("residency enabled above");
        if tier.peak_bytes() > budget {
            return Err(Error::Graph(format!(
                "peak resident bytes {} exceed the {budget}-byte budget at {nodes} nodes",
                tier.peak_bytes()
            )));
        }
        let m = tier.metrics();
        Ok(ResidencyRow {
            nodes: n,
            edges: g.num_edges(),
            shards: res.plan().num_shards(),
            table,
            budget_bytes: budget,
            peak_bytes: tier.peak_bytes(),
            unbounded_bytes: tier.unbounded_bytes(),
            graph_encoded_bytes: compact.encoded_bytes(),
            graph_seed_bytes: compact.seed_bytes(),
            compression_ratio: compact.compression_ratio(),
            hits: m.counter_value("resident.hits"),
            misses: m.counter_value("resident.misses"),
            evictions: m.counter_value("resident.evictions"),
            prefetch_issued: m.counter_value("resident.prefetch_issued"),
            prefetch_hits: m.counter_value("resident.prefetch_hits"),
            hit_rate: tier.hit_rate(),
            shard_encodes: res.shard_encodes(),
            batches_per_round,
            rounds,
            resident_wall_s: timed.then_some(res_wall),
            seed_wall_s: timed.then_some(seed_wall),
        })
    }

    /// Post-hoc metrics view — the `.metrics.json` sidecar the CLI
    /// writes next to `BENCH_residency.json`.  Wall-clock fields are
    /// excluded so the snapshot stays byte-deterministic.
    pub fn metrics_snapshot(&self) -> MetricsRegistry {
        let m = MetricsRegistry::new();
        m.inc("residency.scales", self.rows.len() as u64);
        for r in &self.rows {
            m.inc("residency.hits", r.hits);
            m.inc("residency.misses", r.misses);
            m.inc("residency.evictions", r.evictions);
            m.inc("residency.prefetch_hits", r.prefetch_hits);
            m.inc("residency.shard_encodes", r.shard_encodes);
            m.raise_gauge("residency.peak_bytes", r.peak_bytes as f64);
            m.raise_gauge("residency.compression_ratio", r.compression_ratio);
            m.observe("residency.hit_rate", r.hit_rate);
        }
        m
    }

    pub fn render(&self) -> Table {
        let mut t = Table::new(
            "E16 — residency: LiveJournal-shape graphs under a byte budget",
            &[
                "Nodes",
                "Edges",
                "Shards",
                "Budget B",
                "Peak B",
                "Unbounded B",
                "CSR ratio",
                "Hit rate",
                "Overhead",
            ],
        );
        for r in &self.rows {
            t.row(&[
                r.nodes.to_string(),
                r.edges.to_string(),
                r.shards.to_string(),
                r.budget_bytes.to_string(),
                r.peak_bytes.to_string(),
                r.unbounded_bytes.to_string(),
                format!("{:.2}x", r.compression_ratio),
                format!("{:.1}%", r.hit_rate * 100.0),
                r.decode_overhead()
                    .map(|o| format!("{o:.2}x"))
                    .unwrap_or_else(|| "-".into()),
            ]);
        }
        t
    }

    /// The `BENCH_residency.json` artifact.
    pub fn to_json(&self) -> String {
        let num = |v: f64| format!("{v:.6e}");
        let opt = |v: Option<f64>| v.map(&num).unwrap_or_else(|| "null".into());
        let mut rows = Vec::with_capacity(self.rows.len());
        for r in &self.rows {
            rows.push(format!(
                "    {{\"nodes\": {}, \"edges\": {}, \"shards\": {}, \"table\": {}, \
                 \"budget_bytes\": {}, \"peak_bytes\": {}, \"unbounded_bytes\": {}, \
                 \"graph\": {{\"encoded_bytes\": {}, \"seed_bytes\": {}, \
                 \"compression_ratio\": {}}}, \"cache\": {{\"hits\": {}, \"misses\": {}, \
                 \"evictions\": {}, \"prefetch_issued\": {}, \"prefetch_hits\": {}, \
                 \"hit_rate\": {}}}, \"shard_encodes\": {}, \"batches_per_round\": {}, \
                 \"rounds\": {}, \"resident_wall_s\": {}, \"seed_wall_s\": {}, \
                 \"decode_overhead\": {}}}",
                r.nodes,
                r.edges,
                r.shards,
                r.table,
                r.budget_bytes,
                r.peak_bytes,
                r.unbounded_bytes,
                r.graph_encoded_bytes,
                r.graph_seed_bytes,
                num(r.compression_ratio),
                r.hits,
                r.misses,
                r.evictions,
                r.prefetch_issued,
                r.prefetch_hits,
                num(r.hit_rate),
                r.shard_encodes,
                r.batches_per_round,
                r.rounds,
                opt(r.resident_wall_s),
                opt(r.seed_wall_s),
                opt(r.decode_overhead()),
            ));
        }
        let within = self.rows.iter().all(|r| r.peak_bytes <= r.budget_bytes);
        let min_ratio =
            self.rows.iter().map(|r| r.compression_ratio).fold(f64::INFINITY, f64::min);
        let min_hit = self.rows.iter().map(|r| r.hit_rate).fold(f64::INFINITY, f64::min);
        format!(
            "{{\n  \"experiment\": \"residency_sweep\",\n  \"config\": {{\
             \"max_nodes\": {}, \"rounds\": {}, \"budget_shards\": {}, \
             \"degree\": {}, \"quant\": \"exact_i32\"}},\n  \
             \"summary\": {{\"scales\": {}, \"peak_within_budget\": {}, \
             \"min_compression_ratio\": {}, \"min_hit_rate\": {}}},\n  \
             \"rows\": [\n{}\n  ]\n}}\n",
            self.max_nodes,
            self.rounds,
            self.budget_shards,
            RESIDENCY_DEGREE,
            self.rows.len(),
            within,
            num(min_ratio),
            num(min_hit),
            rows.join(",\n"),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::assert_close;

    #[test]
    fn table1_within_one_percent_of_paper() {
        let t = Table1::new().unwrap();
        let err = t.max_relative_error();
        assert!(err < 0.01, "max relative error {err:.4} >= 1%");
        // and the rendered table carries both modeled and paper columns
        let s = t.render().render();
        assert!(s.contains("14.27 µs") && s.contains("Communication"));
    }

    #[test]
    fn fig8_summary_matches_paper_headlines() {
        let f = Fig8::new().unwrap();
        assert_close(f.avg_compute_speedup(), 1400.0, 0.05);
        assert_close(f.avg_comm_speedup(), 790.0, 0.05);
        assert_eq!(f.series.len(), 4);
        assert!(f.summary().contains("paper"));
        assert!(f.render().render().contains("LiveJournal / decentralized"));
    }

    #[test]
    fn table2_renders_all_datasets() {
        let t = table2(2_000).unwrap().render();
        for name in ["LiveJournal", "Collab", "Cora", "Citeseer"] {
            assert!(t.contains(name));
        }
        assert!(t.contains("4847571"));
    }

    /// E9 at the paper's operating point (N=10k, cₛ=10): the uncongested
    /// fabric reproduces the Table 1 gaps exactly — ~123× communication in
    /// centralized's favor, ~10.7× compute in decentralized's favor — and
    /// under the paper's no-contention assumptions the V2X star never
    /// loses, so no crossover exists.
    #[test]
    fn netsim_sweep_reproduces_table1_gaps_at_the_paper_point() {
        let sweep = NetsimSweep::run(
            &GnnWorkload::taxi(),
            &[10_000],
            &[10],
            &NetSimConfig::default(),
        )
        .unwrap();
        assert_eq!(sweep.rows.len(), 1);
        assert!(sweep.max_rel_gap() < 1e-6, "gap {}", sweep.max_rel_gap());
        assert_close(sweep.avg_comm_gap(), 123.0, 0.02);
        assert_close(sweep.avg_compute_gap(), 10.7, 0.02);
        assert!(sweep.crossover().is_none());
    }

    /// E9 with a finite leader NIC: uplink contention grows linearly with
    /// the fleet while the cluster-head overlay gathers in parallel — the
    /// semi-decentralized crossover the conclusion predicts appears.
    #[test]
    fn netsim_sweep_contention_reveals_the_semi_crossover() {
        let cfg = NetSimConfig { rx_ports: Some(64), ..Default::default() };
        let sweep =
            NetsimSweep::run(&GnnWorkload::taxi(), &[200, 1_000, 5_000], &[10], &cfg).unwrap();
        let x = sweep.crossover().expect("contended uplinks must reveal a crossover");
        // 200 devices still fit the leader's ports; 1000 do not.
        assert_eq!(x.nodes, 1_000);
        let json = sweep.to_json();
        assert!(json.contains("\"experiment\": \"netsim_sweep\""));
        assert!(json.contains("\"crossover\": {\"nodes\": 1000"));
        assert!(json.contains("\"rx_ports\": 64"));
        let table = sweep.render().render();
        assert!(table.contains("semi"));
        assert!(table.contains("1000"));
    }

    /// The parallel sweep driver is observably identical to the
    /// sequential path: same rows, same `BENCH_netsim.json` bytes, for
    /// the same seed — the determinism the perf-trajectory artifact
    /// relies on.
    #[test]
    fn parallel_sweep_is_byte_identical_to_sequential() {
        let w = GnnWorkload::taxi();
        let cfg = NetSimConfig {
            rx_ports: Some(8),
            link_jitter: 0.2,
            seed: 9,
            ..Default::default()
        };
        let seq =
            NetsimSweep::run_with_threads(&w, &[200, 400], &[5, 10], &cfg, 1).unwrap();
        let par4 =
            NetsimSweep::run_with_threads(&w, &[200, 400], &[5, 10], &cfg, 4).unwrap();
        assert_eq!(seq.rows.len(), 4);
        assert_eq!(seq.to_json(), par4.to_json());
        // ... and the auto-threaded entry point agrees too.
        let auto = NetsimSweep::run(&w, &[200, 400], &[5, 10], &cfg).unwrap();
        assert_eq!(seq.to_json(), auto.to_json());
    }

    /// E11 acceptance: the tuned semi-decentralized point beats both pure
    /// settings on total latency for at least one dataset (LiveJournal:
    /// huge fleet → centralized compute explodes; tiny 1-byte features →
    /// the hybrid's V2X overlay costs almost nothing).
    #[test]
    fn hybrid_sweep_tuned_semi_beats_both_pure_settings_somewhere() {
        let sweep = HybridSweep::run_with_threads(400, 1).unwrap();
        assert_eq!(sweep.rows.len(), 5);
        let wins = sweep.hybrid_wins();
        assert!(!wins.is_empty(), "no dataset where the hybrid wins");
        assert!(wins.iter().any(|r| r.dataset == "LiveJournal"));
        for r in &sweep.rows {
            // The argmin never loses to a pure point inside its own grid
            // region, and the baselines are genuinely evaluated.
            assert!(r.best.score.latency.as_s() > 0.0);
            assert!(r.speedup_vs_best_pure() > 0.0);
            assert!(r.grid_points == 41, "{}: {} points", r.dataset, r.grid_points);
            assert!(r.pareto_points >= 1 && r.pareto_points <= r.grid_points);
        }
        // The top-3 refinement attached a packet-level cross-check to the
        // winner (the argmin is by definition among the top-3).  The
        // uncongested fabric never exceeds the analytic clustered score:
        // it prices the same transfers minus the boundary-relay term the
        // intra-edge fraction adds analytically.
        let lj = sweep.rows.iter().find(|r| r.dataset == "LiveJournal").unwrap();
        let check = lj.best.simulated.expect("winner must carry a netsim check");
        assert!(check.nodes <= 400);
        assert!(check.simulated.as_s() > 0.0);
        assert!(
            check.simulated.as_s() <= check.analytic.as_s() * (1.0 + 1e-9),
            "sim {} vs analytic {}",
            check.simulated,
            check.analytic
        );
        let json = sweep.to_json();
        assert!(json.contains("\"experiment\": \"hybrid_autotune\""));
        assert!(json.contains("\"hybrid_wins\": true"));
        assert!(json.contains("LiveJournal"));
        let table = sweep.render().render();
        assert!(table.contains("semi") && table.contains("Taxi"));
    }

    /// E11 determinism: the parallel sweep emits byte-identical
    /// `BENCH_hybrid.json` to the sequential run.
    #[test]
    fn hybrid_sweep_parallel_is_byte_identical_to_sequential() {
        let seq = HybridSweep::run_with_threads(300, 1).unwrap();
        let par4 = HybridSweep::run_with_threads(300, 4).unwrap();
        assert_eq!(seq.rows, par4.rows);
        assert_eq!(seq.to_json(), par4.to_json());
        let auto = HybridSweep::run(300).unwrap();
        assert_eq!(seq.to_json(), auto.to_json());
    }

    /// E12: every Table 2 shape (plus taxi) serves through the engine at
    /// artifact-table granularity — samples wider than the 64-row table
    /// shard, single-table samples do not, the engine's tensor cache
    /// misses exactly shards × rounds, and one round batches every node.
    #[test]
    fn serving_sweep_shards_the_table2_shapes() {
        let sweep = ServingSweep::run_with_threads(256, 2, 1, false).unwrap();
        assert_eq!(sweep.rows.len(), 5);
        for r in &sweep.rows {
            assert!(r.sample_nodes <= 256);
            assert!(r.max_slots <= r.table, "{}: shard overflows table", r.dataset);
            assert_eq!(r.table_builds, (r.shards * r.rounds) as u64, "{}", r.dataset);
            // One full round covers every node: at least ⌈members/batch⌉
            // batches summed over shards, and at least one per shard.
            assert!(r.batches_per_round >= r.shards as u64, "{}", r.dataset);
            assert!(r.batches_per_round >= (r.sample_nodes as u64).div_ceil(16));
            assert!(r.cent_modeled.as_s() > 0.0 && r.semi_modeled.as_s() > 0.0);
            assert!(r.wall_s.is_none(), "untimed run must not carry walls");
            // 256-node samples do not fit the 64-row artifact table.
            if r.sample_nodes > r.table {
                assert!(r.shards > 1, "{}: expected sharding", r.dataset);
            }
        }
        let json = sweep.to_json();
        assert!(json.contains("\"experiment\": \"sharded_serving\""));
        assert!(json.contains("\"wall_s\": null"));
        assert!(json.contains("LiveJournal"));
        assert!(sweep.render().render().contains("Taxi"));
    }

    /// E12 determinism: the parallel sweep emits byte-identical untimed
    /// `BENCH_serving.json` to the sequential run.
    #[test]
    fn serving_sweep_parallel_is_byte_identical_to_sequential() {
        let seq = ServingSweep::run_with_threads(200, 1, 1, false).unwrap();
        let par4 = ServingSweep::run_with_threads(200, 1, 4, false).unwrap();
        assert_eq!(seq.rows, par4.rows);
        assert_eq!(seq.to_json(), par4.to_json());
        // The timed entry point measures real walls on the same rows.
        let timed = ServingSweep::run_with_threads(200, 1, 2, true).unwrap();
        assert!(timed.rows.iter().all(|r| r.wall_s.is_some()));
        let strip = |s: &ServingRow| ServingRow { wall_s: None, ..s.clone() };
        let stripped: Vec<ServingRow> = timed.rows.iter().map(strip).collect();
        assert_eq!(stripped, seq.rows);
    }

    /// E13 acceptance: under sustained load the winner flips — the
    /// leader's single queue wins the unloaded regime, saturates as the
    /// offered rate approaches its gather ceiling, and the cluster-head
    /// overlay overtakes it at a *finite, reported* request rate.
    #[test]
    fn traffic_sweep_finds_a_finite_semi_crossover_under_load() {
        let sweep = TrafficSweep::run_with_threads(200, 2_000, 1).unwrap();
        assert_eq!(sweep.rows.len(), 4);
        let hot = TRAFFIC_REL_RATES.len() - 1;
        for r in &sweep.rows {
            assert_eq!(r.points.len(), TRAFFIC_REL_RATES.len() * 3);
            // Low load: the fast V2X gather wins (the one-shot Fig. 8
            // regime the paper measures).
            let c0 = r.point(0, "centralized");
            let s0 = r.point(0, "semi");
            assert!(
                c0.p95_s < s0.p95_s,
                "{}: leader must win at low load ({} vs {})",
                r.dataset,
                c0.p95_s,
                s0.p95_s
            );
            // The decentralized ad-hoc exchange never wins a latency SLO.
            let d0 = r.point(0, "decentralized");
            assert!(d0.p95_s > s0.p95_s, "{}", r.dataset);
            // Deep overload: the leader saturates...
            let c_hot = r.point(hot, "centralized");
            assert!(c_hot.utilization > 0.9, "{}: util {}", r.dataset, c_hot.utilization);
            assert!(c_hot.p95_s > c0.p95_s * 2.0, "{}: no congestion growth", r.dataset);
            // ...and every point's accounting is consistent.
            for p in &r.points {
                assert!(p.littles_gap < 1e-9, "{} {}: {}", r.dataset, p.setting, p.littles_gap);
                assert!(p.utilization > 0.0 && p.utilization <= 1.0 + 1e-12);
                assert!(p.offered > 0 && p.p95_s >= p.p50_s && p.p99_s >= p.p95_s);
            }
        }
        // The headline: a finite centralized→semi crossover rate exists
        // (LiveJournal's fleet and Citeseer's fat messages both flip).
        let lj = sweep.rows.iter().find(|r| r.dataset == "LiveJournal").unwrap();
        let x = lj.crossover_per_s.expect("LiveJournal must have a crossover");
        assert!(x.is_finite() && x > 0.0 && x <= 2.0 * lj.sat_rate_per_s);
        let cs = sweep.rows.iter().find(|r| r.dataset == "Citeseer").unwrap();
        assert!(cs.crossover_per_s.is_some(), "Citeseer must have a crossover");
        assert!(sweep.max_littles_gap() < 1e-9);
        assert!(sweep.summary().contains("req/s"));

        let json = sweep.to_json();
        assert!(json.contains("\"experiment\": \"traffic_sweep\""));
        assert!(json.contains("\"crossovers\": [{\"dataset\": "));
        assert!(json.contains("LiveJournal"));
        let table = sweep.render().render();
        assert!(table.contains("semi") && table.contains("Citeseer"));
    }

    /// E13 determinism: the parallel sweep emits byte-identical
    /// `BENCH_traffic.json` to the sequential run, per seed.
    #[test]
    fn traffic_sweep_parallel_is_byte_identical_to_sequential() {
        let seq = TrafficSweep::run_with_threads(150, 400, 1).unwrap();
        let par4 = TrafficSweep::run_with_threads(150, 400, 4).unwrap();
        assert_eq!(seq.rows, par4.rows);
        assert_eq!(seq.to_json(), par4.to_json());
        let again = TrafficSweep::run_with_threads(150, 400, 1).unwrap();
        assert_eq!(seq.to_json(), again.to_json());
    }

    /// E14 structure and the deterministic scenario couplings: the
    /// fault-free scenarios report zero downtime; common random numbers
    /// make the baseline and hetero centralized points bit-identical
    /// (both homogeneous, both fault-free, same seed) and the r1 / r2
    /// centralized points bit-identical (the leader has no replicas, so
    /// both take the same fixed outages); r2's replica-served semi and
    /// decentralized points never go dark; and every executed r1 window
    /// bills exactly its setting's failover total (MTTR == the bill).
    #[test]
    fn fault_sweep_accounts_downtime_and_replicas_deterministically() {
        let sweep = FaultSweep::run_with_threads(150, 250, 1).unwrap();
        assert_eq!(sweep.rows.len(), 4);
        let mut executed_windows = 0usize;
        for r in &sweep.rows {
            assert_eq!(r.scenarios.len(), FAULT_SCENARIOS.len());
            for s in &r.scenarios {
                assert_eq!(s.points.len(), TRAFFIC_REL_RATES.len() * 3);
            }
            assert!(r.failover_s.iter().all(|&f| f.is_finite() && f > 0.0));
            // The leader's bill (all rows over the uplink) dwarfs a
            // head's (one cluster over local hops).
            assert!(r.failover_s[0] > r.failover_s[1]);
            let base = r.scenario("baseline");
            let het = r.scenario("hetero");
            let r1 = r.scenario("faulted_r1");
            let r2 = r.scenario("faulted_r2");
            for s in [base, het] {
                for p in &s.points {
                    assert_eq!(p.fault_windows, 0);
                    assert_eq!(p.downtime_s, 0.0);
                    assert_eq!(p.availability, 1.0);
                }
            }
            for ri in 0..TRAFFIC_REL_RATES.len() {
                let (b, hc) = (base.point(ri, "centralized"), het.point(ri, "centralized"));
                assert_eq!(b.p95_s.to_bits(), hc.p95_s.to_bits());
                let (c1, c2) = (r1.point(ri, "centralized"), r2.point(ri, "centralized"));
                assert_eq!(c1.p95_s.to_bits(), c2.p95_s.to_bits());
                assert_eq!(c1.downtime_s.to_bits(), c2.downtime_s.to_bits());
                for (si, s) in ["semi", "decentralized"].into_iter().enumerate() {
                    let p2 = r2.point(ri, s);
                    assert_eq!(p2.downtime_s, 0.0, "replicas must not go dark");
                    assert_eq!(p2.availability, 1.0);
                    let p1 = r1.point(ri, s);
                    executed_windows += p1.fault_windows;
                    if p1.fault_windows > 0 {
                        let bill = r.failover_s[si + 1];
                        assert!(
                            (p1.mttr_s - bill).abs() <= 1e-9 * bill.max(1.0),
                            "{} {s}: mttr {} != bill {}",
                            r.dataset,
                            p1.mttr_s,
                            bill
                        );
                        assert!(p1.availability < 1.0);
                    }
                }
            }
        }
        // ~3 expected windows per faulted point over 48 points.
        assert!(executed_windows > 0, "no crash window executed anywhere");
        assert!(sweep.max_littles_gap() < 1e-9);
    }

    /// The E14 headline: failures inflate the centralized leader's p95
    /// more than the semi overlay's (its failover re-uploads the whole
    /// store over the uplink, and its single queue absorbs the full
    /// system rate), which can only pull the semi-beats-centralized
    /// crossover earlier; heterogeneity alone inflates semi; and r = 2
    /// replication dominates r = 1 at the same crash times — strictly
    /// higher SLO attainment, or (when no swept arrival straddles a
    /// window closely enough to flip an SLO verdict) the tie broken by
    /// strictly higher availability.  Plus the parallel byte-identity
    /// contract for `BENCH_faults.json`.
    #[test]
    fn fault_sweep_headline_and_parallel_identity() {
        let seq = FaultSweep::run_with_threads(150, 250, 1).unwrap();
        let h = seq.headline();
        assert!(h.cent_inflation_s > 0.0, "failures must cost the leader: {h:?}");
        assert!(h.cent_inflation_s > h.semi_inflation_s, "{h:?}");
        assert!(h.hetero_semi_inflation_s > 0.0, "{h:?}");
        for r in &seq.rows {
            let x0 = r.scenario("baseline").crossover_per_s.unwrap_or(f64::INFINITY);
            let x1 = r.scenario("faulted_r1").crossover_per_s.unwrap_or(f64::INFINITY);
            assert!(x1 <= x0, "{}: faults must not delay the crossover", r.dataset);
        }
        assert!(
            h.slo_r2 > h.slo_r1 || (h.slo_r2 >= h.slo_r1 && h.availability_r2 > h.availability_r1),
            "replication must dominate: {h:?}"
        );
        assert!(h.availability_r2 == 1.0 && h.availability_r1 < 1.0, "{h:?}");
        assert!(h.overload_r2_s < h.overload_r1_s, "degraded service beats outages: {h:?}");

        let json = seq.to_json();
        assert!(json.contains("\"experiment\": \"fault_sweep\""));
        assert!(json.contains("\"scenario\": \"faulted_r2\""));
        assert!(seq.summary().contains("r=2 SLO attainment"));
        assert!(seq.render().render().contains("faulted_r1"));

        let par4 = FaultSweep::run_with_threads(150, 250, 4).unwrap();
        assert_eq!(seq.rows, par4.rows);
        assert_eq!(json, par4.to_json());
        let again = FaultSweep::run_with_threads(150, 250, 1).unwrap();
        assert_eq!(json, again.to_json());
    }

    /// The E15 headline: over the full scenario day the adaptive
    /// controller's SLO attainment is at least every static rung's for
    /// at least one dataset, and in *every* cell it trails the best
    /// static by no more than the priced switch overhead (the requests
    /// its own switches touched).  Switches respect the min-dwell
    /// everywhere — the controller never flaps.
    #[test]
    fn controller_sweep_adaptive_wins_within_priced_switch_overhead() {
        let sweep = ControllerSweep::run_with_threads(150, 600, 1).unwrap();
        assert_eq!(sweep.rows.len(), 4);
        for r in &sweep.rows {
            assert_eq!(r.scenarios.len(), CTRL_SCENARIOS.len());
            assert!(r.slo_s > 0.0 && r.sat_rate_per_s > 0.0);
            // The gain gate admits only real capacity jumps, and every
            // rung's switch-in bill is a positive priced pause.
            for w in r.ladder.windows(2) {
                assert!(
                    w[1].sat_per_s >= CTRL_LADDER_GAIN * w[0].sat_per_s,
                    "{}: ladder gain violated",
                    r.dataset
                );
            }
            assert!(r.ladder.iter().all(|g| g.switch_cost_s > 0.0));
            for s in &r.scenarios {
                assert_eq!(s.statics.len(), r.ladder.len());
                assert!(s.offered > 0);
                assert!(s.dwell_ok, "{} {}: dwell violated", r.dataset, s.scenario);
                // Every executed switch is priced and billed: the
                // downtime ledger is exactly the sum of the recorded
                // pause costs (bit-exact accumulation).
                let billed: f64 = s.switches.iter().map(|w| w.cost.as_s()).sum();
                assert!(
                    (s.switch_downtime_s - billed).abs() <= 1e-12 * billed.max(1.0),
                    "{} {}: downtime {} != billed {}",
                    r.dataset,
                    s.scenario,
                    s.switch_downtime_s,
                    billed
                );
                assert!(s.switch_affected >= s.switches.iter().map(|w| w.moved).sum());
            }
        }
        // At least one dataset carries a real multi-rung ladder and the
        // controller genuinely acts somewhere.
        assert!(sweep.rows.iter().any(|r| r.ladder.len() >= 2));
        let h = sweep.headline();
        assert!(h.total_switches > 0, "controller never switched: {h:?}");
        assert!(h.adaptive_win_datasets >= 1, "adaptive never wins a day: {h:?}");
        assert!(h.bound_ok, "regret exceeds priced switch overhead: {h:?}");
        assert!(h.dwell_ok, "{h:?}");
        assert!(sweep.max_littles_gap() < 1e-9, "{}", sweep.max_littles_gap());

        let json = sweep.to_json();
        assert!(json.contains("\"experiment\": \"controller_sweep\""));
        assert!(json.contains("\"scenario\": \"linkfault\""));
        assert!(json.contains("\"adaptive_win_datasets\": "));
        assert!(sweep.summary().contains("adaptive"));
        assert!(sweep.render().render().contains("diurnal"));
    }

    /// E15 determinism: the parallel sweep emits byte-identical
    /// `BENCH_controller.json` to the sequential run, per seed.
    #[test]
    fn controller_sweep_parallel_is_byte_identical_to_sequential() {
        let seq = ControllerSweep::run_with_threads(150, 400, 1).unwrap();
        let par4 = ControllerSweep::run_with_threads(150, 400, 4).unwrap();
        assert_eq!(seq.rows, par4.rows);
        assert_eq!(seq.to_json(), par4.to_json());
        let again = ControllerSweep::run_with_threads(150, 400, 1).unwrap();
        assert_eq!(seq.to_json(), again.to_json());
    }

    /// E16 at the grid's warm-up scale: the budget genuinely binds
    /// (unbounded footprint exceeds it, evictions happen), the peak
    /// stays under it, the compact CSR compresses a skewed graph, and
    /// the sequential fetch scan rides the prefetch.  The row itself
    /// errors on resident/seed digest divergence, so a clean run *is*
    /// the bit-identity assertion.
    #[test]
    fn residency_sweep_holds_the_budget_and_rides_the_prefetch() {
        let sweep =
            ResidencySweep::run_with_threads(10_000, 2, RESIDENCY_BUDGET_SHARDS, 1, false)
                .unwrap();
        assert_eq!(sweep.rows.len(), 1);
        let r = &sweep.rows[0];
        assert_eq!(r.nodes, 10_000);
        assert!(r.shards > 1, "grid scale must shard: {r:?}");
        assert!(r.peak_bytes <= r.budget_bytes, "{r:?}");
        assert!(r.unbounded_bytes > r.budget_bytes, "budget must actually bind: {r:?}");
        assert!(r.evictions > 0, "{r:?}");
        assert!(r.compression_ratio > 1.0, "{r:?}");
        assert!(r.hit_rate > 0.5, "prefetch should carry the shard-order scan: {r:?}");
        assert_eq!(r.shard_encodes, (r.shards * r.rounds) as u64);
        assert_eq!(r.misses + r.hits, r.batches_per_round * r.rounds as u64);
        let json = sweep.to_json();
        assert!(json.contains("\"experiment\": \"residency_sweep\""));
        assert!(json.contains("\"peak_within_budget\": true"));
        assert!(json.contains("\"resident_wall_s\": null"));
        assert!(sweep.render().render().contains("Hit rate"));
        assert!(sweep.metrics_snapshot().to_json().contains("residency.peak_bytes"));
    }

    /// E16 determinism: untimed sweeps emit byte-identical
    /// `BENCH_residency.json` at every thread count, and rerunning is
    /// reproducible.
    #[test]
    fn residency_sweep_parallel_is_byte_identical_to_sequential() {
        let seq = ResidencySweep::run_with_threads(10_000, 2, 2, 1, false).unwrap();
        let par4 = ResidencySweep::run_with_threads(10_000, 2, 2, 4, false).unwrap();
        assert_eq!(seq.rows, par4.rows);
        assert_eq!(seq.to_json(), par4.to_json());
        let again = ResidencySweep::run_with_threads(10_000, 2, 2, 1, false).unwrap();
        assert_eq!(seq.to_json(), again.to_json());
        assert_eq!(
            seq.metrics_snapshot().to_json(),
            par4.metrics_snapshot().to_json(),
        );
    }

    #[test]
    fn scaling_improves_then_saturates_and_costs_power() {
        let rows = scaling_sweep(&GnnWorkload::taxi()).unwrap();
        // latency non-increasing
        for w in rows.windows(2) {
            assert!(w[1].1 <= w[0].1, "latency must not increase with crossbars");
            assert!(w[1].2 >= w[0].2, "power must not decrease with crossbars");
        }
        // saturates: taxi has 4 column groups → no gain past 4 crossbars
        let at4 = rows.iter().find(|r| r.0 == 4).unwrap().1;
        let at32 = rows.iter().find(|r| r.0 == 32).unwrap().1;
        assert_close(at4.as_us(), at32.as_us(), 1e-9);
        // but 1 → 4 is a real speedup
        let at1 = rows.iter().find(|r| r.0 == 1).unwrap().1;
        assert!(at1 / at4 > 2.0);
    }
}
