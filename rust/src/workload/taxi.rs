//! Taxi-city workload generator (paper §4.2 / Fig. 6).
//!
//! Synthesizes a city of taxis with the three heterogeneous edge types of
//! the hetGNN (road connectivity, location proximity, destination
//! similarity) and per-taxi demand/supply history tensors for the m×n
//! region around each node — the synthetic stand-in for the proprietary
//! fleet trace of paper ref [26] (DESIGN.md §2).

use crate::error::{Error, Result};
use crate::graph::Csr;
use crate::testing::Rng;
use crate::units::Time;

/// The hetGNN's edge types.
pub const EDGE_TYPES: usize = 3;

/// Diurnal taxi-demand intensity curve (the arrival-rate counterpart of
/// the `2 + sin(phase)` demand base the history tensors carry): a request
/// rate that swings sinusoidally around `base_rate` with the given
/// `period`.  Rates are clamped at zero, so any amplitude is safe — the
/// curve never goes negative (asserted in tests).  This is the E13
/// traffic engine's open-loop diurnal arrival process (§4.2's sustained
/// taxi stream, which the one-shot round experiments never modeled).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DiurnalCurve {
    /// Mean request rate (requests/second) over one period.
    pub base_rate: f64,
    /// Relative swing: rate peaks at `base·(1+amplitude)` and troughs at
    /// `base·(1−amplitude)` (clamped at 0 when `amplitude > 1`).
    pub amplitude: f64,
    /// One demand cycle (a scaled "day").
    pub period: Time,
}

impl DiurnalCurve {
    pub fn new(base_rate: f64, amplitude: f64, period: Time) -> Result<DiurnalCurve> {
        if !(base_rate > 0.0) || !(period.as_s() > 0.0) || !amplitude.is_finite() {
            return Err(Error::Graph("diurnal curve needs positive rate/period".into()));
        }
        Ok(DiurnalCurve { base_rate, amplitude: amplitude.abs(), period })
    }

    /// Instantaneous rate at absolute time `t` (periodic, never negative).
    pub fn rate(&self, t: Time) -> f64 {
        let phase = t.as_s() / self.period.as_s() * std::f64::consts::TAU;
        (self.base_rate * (1.0 + self.amplitude * phase.sin())).max(0.0)
    }

    /// The curve's maximum rate — the thinning envelope the Poisson
    /// arrival generator rejects against.
    pub fn peak_rate(&self) -> f64 {
        self.base_rate * (1.0 + self.amplitude)
    }
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct TaxiCityConfig {
    /// Number of taxis (the paper's study: 10 000).
    pub taxis: usize,
    /// City extent in meters (square).
    pub city_meters: f64,
    /// Taxis within this radius get a *location proximity* edge.
    pub proximity_radius: f64,
    /// Taxis whose destinations fall within this radius get a
    /// *destination similarity* edge.
    pub destination_radius: f64,
    /// Road-graph degree (nearest-neighbor road connections).
    pub road_degree: usize,
    /// Demand-grid history length P.
    pub hist: usize,
    /// Demand-grid size (m = n).
    pub grid: usize,
    pub seed: u64,
}

impl Default for TaxiCityConfig {
    fn default() -> Self {
        TaxiCityConfig {
            taxis: 10_000,
            city_meters: 20_000.0,
            proximity_radius: 500.0,
            destination_radius: 800.0,
            road_degree: 4,
            hist: 12,
            grid: 8,
            seed: 2023,
        }
    }
}

/// A generated taxi city.
#[derive(Debug)]
pub struct TaxiCity {
    pub config: TaxiCityConfig,
    /// Taxi positions (x, y) in meters.
    pub positions: Vec<(f64, f64)>,
    /// Taxi destinations (x, y) in meters.
    pub destinations: Vec<(f64, f64)>,
    /// One graph per edge type: road / proximity / destination.
    pub graphs: [Csr; EDGE_TYPES],
    /// Per-taxi demand history, `[taxis][hist * grid * grid * 2]`
    /// (demand + supply channels, flattened frame-major).
    pub history: Vec<Vec<f32>>,
}

fn dist2(a: (f64, f64), b: (f64, f64)) -> f64 {
    let dx = a.0 - b.0;
    let dy = a.1 - b.1;
    dx * dx + dy * dy
}

impl TaxiCity {
    pub fn generate(config: TaxiCityConfig) -> Result<TaxiCity> {
        if config.taxis < 2 {
            return Err(Error::Graph("need at least 2 taxis".into()));
        }
        if config.grid == 0 || config.hist == 0 {
            return Err(Error::Graph("grid and hist must be > 0".into()));
        }
        let mut rng = Rng::new(config.seed);
        let n = config.taxis;
        let positions: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.f64_in(0.0, config.city_meters), rng.f64_in(0.0, config.city_meters)))
            .collect();
        let destinations: Vec<(f64, f64)> = (0..n)
            .map(|_| (rng.f64_in(0.0, config.city_meters), rng.f64_in(0.0, config.city_meters)))
            .collect();

        // Spatial hash so edge building is ~O(n) rather than O(n²).
        let cell = config.proximity_radius.max(config.destination_radius).max(1.0);
        let buckets = |pts: &[(f64, f64)]| {
            let mut map = std::collections::HashMap::<(i64, i64), Vec<usize>>::new();
            for (i, p) in pts.iter().enumerate() {
                map.entry(((p.0 / cell) as i64, (p.1 / cell) as i64)).or_default().push(i);
            }
            map
        };
        let near = |pts: &[(f64, f64)],
                    map: &std::collections::HashMap<(i64, i64), Vec<usize>>,
                    i: usize,
                    radius: f64| {
            let p = pts[i];
            let (cx, cy) = ((p.0 / cell) as i64, (p.1 / cell) as i64);
            let mut out = Vec::new();
            for dx in -1..=1 {
                for dy in -1..=1 {
                    if let Some(cands) = map.get(&(cx + dx, cy + dy)) {
                        for &j in cands {
                            if j != i && dist2(p, pts[j]) <= radius * radius {
                                out.push(j);
                            }
                        }
                    }
                }
            }
            out
        };

        let pos_map = buckets(&positions);
        let dst_map = buckets(&destinations);

        // Road connectivity: each taxi links to its nearest road peers
        // (approximated by the closest in-radius neighbors, capped).
        let mut road_edges = Vec::new();
        let mut prox_edges = Vec::new();
        let mut dest_edges = Vec::new();
        for i in 0..n {
            let mut cand = near(&positions, &pos_map, i, config.proximity_radius);
            cand.sort_by(|&a, &b| {
                dist2(positions[i], positions[a])
                    .partial_cmp(&dist2(positions[i], positions[b]))
                    .unwrap()
            });
            for &j in cand.iter().take(config.road_degree) {
                road_edges.push((i, j));
            }
            for &j in &cand {
                prox_edges.push((i, j));
            }
            for j in near(&destinations, &dst_map, i, config.destination_radius) {
                dest_edges.push((i, j));
            }
        }

        let graphs = [
            Csr::from_edges(n, &road_edges)?,
            Csr::from_edges(n, &prox_edges)?,
            Csr::from_edges(n, &dest_edges)?,
        ];

        // Demand/supply history: diurnal base + hotspot bumps + noise,
        // kept non-negative.
        let frame = config.grid * config.grid;
        let mut history = Vec::with_capacity(n);
        for i in 0..n {
            let mut h = Vec::with_capacity(config.hist * frame * 2);
            let hotspot = (positions[i].0 / config.city_meters, positions[i].1 / config.city_meters);
            for t in 0..config.hist {
                let phase = (t as f64 / config.hist as f64) * std::f64::consts::TAU;
                for ch in 0..2 {
                    for gy in 0..config.grid {
                        for gx in 0..config.grid {
                            let fx = gx as f64 / config.grid as f64;
                            let fy = gy as f64 / config.grid as f64;
                            let bump = (-8.0
                                * ((fx - hotspot.0).powi(2) + (fy - hotspot.1).powi(2)))
                            .exp();
                            let base = 2.0 + (phase + ch as f64).sin();
                            let noise = rng.f64_in(0.0, 0.3);
                            h.push((base + 3.0 * bump + noise) as f32);
                        }
                    }
                }
            }
            history.push(h);
        }

        Ok(TaxiCity { config, positions, destinations, graphs, history })
    }

    pub fn num_taxis(&self) -> usize {
        self.positions.len()
    }

    /// Combined multi-relation neighbor view of one taxi.
    pub fn neighbors(&self, taxi: usize, edge_type: usize) -> &[usize] {
        self.graphs[edge_type].neighbors(taxi)
    }

    /// Flattened history frame count per taxi.
    pub fn history_len(&self) -> usize {
        self.config.hist * self.config.grid * self.config.grid * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> TaxiCityConfig {
        TaxiCityConfig { taxis: 200, city_meters: 2_000.0, seed: 7, ..Default::default() }
    }

    #[test]
    fn generates_three_graphs_over_all_taxis() {
        let city = TaxiCity::generate(small()).unwrap();
        assert_eq!(city.num_taxis(), 200);
        for g in &city.graphs {
            assert_eq!(g.num_nodes(), 200);
            g.validate().unwrap();
        }
        // proximity super-graph includes the road graph's endpoints
        assert!(city.graphs[1].num_edges() >= city.graphs[0].num_edges());
    }

    #[test]
    fn proximity_edges_respect_the_radius() {
        let city = TaxiCity::generate(small()).unwrap();
        let r2 = city.config.proximity_radius * city.config.proximity_radius;
        for i in 0..city.num_taxis() {
            for &j in city.neighbors(i, 1) {
                assert!(dist2(city.positions[i], city.positions[j]) <= r2 + 1e-6);
            }
        }
    }

    #[test]
    fn destination_edges_use_destinations() {
        let city = TaxiCity::generate(small()).unwrap();
        let r2 = city.config.destination_radius * city.config.destination_radius;
        for i in 0..city.num_taxis() {
            for &j in city.neighbors(i, 2) {
                assert!(dist2(city.destinations[i], city.destinations[j]) <= r2 + 1e-6);
            }
        }
    }

    #[test]
    fn road_degree_is_capped() {
        let city = TaxiCity::generate(small()).unwrap();
        for i in 0..city.num_taxis() {
            assert!(city.graphs[0].degree(i) <= city.config.road_degree);
        }
    }

    #[test]
    fn history_has_model_shape_and_is_nonnegative() {
        let cfg = small();
        let city = TaxiCity::generate(cfg).unwrap();
        // P=12, 8×8 grid, 2 channels → 1536 values = hetGNN fin × P.
        assert_eq!(city.history_len(), 12 * 8 * 8 * 2);
        for h in &city.history {
            assert_eq!(h.len(), city.history_len());
            assert!(h.iter().all(|v| *v >= 0.0));
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = TaxiCity::generate(small()).unwrap();
        let b = TaxiCity::generate(small()).unwrap();
        assert_eq!(a.positions, b.positions);
        assert_eq!(a.graphs[2], b.graphs[2]);
        assert_eq!(a.history[13], b.history[13]);
    }

    #[test]
    fn rejects_degenerate_configs() {
        assert!(TaxiCity::generate(TaxiCityConfig { taxis: 1, ..small() }).is_err());
        assert!(TaxiCity::generate(TaxiCityConfig { grid: 0, ..small() }).is_err());
    }

    #[test]
    fn diurnal_curve_is_periodic_and_nonnegative() {
        let c = DiurnalCurve::new(100.0, 0.8, Time::s(2.0)).unwrap();
        // Mean over samples ≈ base, extremes at ±amplitude.
        for k in 0..200 {
            let t = Time::s(k as f64 * 0.017);
            let r = c.rate(t);
            assert!(r >= 0.0 && r <= c.peak_rate() + 1e-9);
            // Periodicity: one full period later, the same rate.
            let r2 = c.rate(t + c.period);
            assert!((r - r2).abs() < 1e-6 * c.base_rate, "t={t}: {r} vs {r2}");
        }
        assert!((c.rate(Time::s(0.5)) - 180.0).abs() < 1e-9, "peak at quarter period");
        assert!((c.rate(Time::s(1.5)) - 20.0).abs() < 1e-9, "trough at three quarters");
        assert!((c.peak_rate() - 180.0).abs() < 1e-12);
    }

    #[test]
    fn diurnal_curve_clamps_overdeep_troughs_at_zero() {
        // amplitude > 1 would go negative on a pure sinusoid; the curve
        // clamps instead, so thinning acceptance stays a probability.
        let c = DiurnalCurve::new(50.0, 1.5, Time::s(1.0)).unwrap();
        assert_eq!(c.rate(Time::s(0.75)), 0.0);
        assert!((c.rate(Time::s(0.25)) - 125.0).abs() < 1e-9);
        // Negative amplitudes normalize to their magnitude.
        let n = DiurnalCurve::new(50.0, -0.5, Time::s(1.0)).unwrap();
        assert_eq!(n.amplitude, 0.5);
    }

    #[test]
    fn diurnal_curve_rejects_degenerate_params() {
        assert!(DiurnalCurve::new(0.0, 0.5, Time::s(1.0)).is_err());
        assert!(DiurnalCurve::new(10.0, 0.5, Time::ZERO).is_err());
        assert!(DiurnalCurve::new(10.0, f64::NAN, Time::s(1.0)).is_err());
    }
}
