//! Application workload generators.
//!
//! DESIGN.md: §4 (workloads drive the experiment code path).

mod taxi;

pub use taxi::{DiurnalCurve, TaxiCity, TaxiCityConfig, EDGE_TYPES};
