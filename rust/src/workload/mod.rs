//! Application workload generators.

mod taxi;

pub use taxi::{TaxiCity, TaxiCityConfig, EDGE_TYPES};
