//! Application workload generators.

mod taxi;

pub use taxi::{DiurnalCurve, TaxiCity, TaxiCityConfig, EDGE_TYPES};
