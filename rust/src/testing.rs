//! Minimal property-based testing framework (offline `proptest` substitute).
//!
//! A deterministic xorshift PRNG plus value generators and a `forall` runner
//! that shrinks failing integer cases by bisection.  Used across the crate's
//! unit tests for coordinator / graph / model invariants.
//!
//! ```no_run
//! // (no_run: doctest binaries miss the xla rpath; see the unit tests
//! // below for executed coverage of the same API.)
//! use ima_gnn::testing::{forall, Rng};
//! forall(64, |rng: &mut Rng| {
//!     let a = rng.u64_in(0, 1000);
//!     let b = rng.u64_in(0, 1000);
//!     assert_eq!(a + b, b + a);
//! });
//! ```
//!
//! DESIGN.md: §8 (determinism contract the property tests lean on).

/// Deterministic xorshift64* PRNG — reproducible across runs and platforms.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Seeded constructor; a zero seed is remapped (xorshift fixpoint).
    pub fn new(seed: u64) -> Rng {
        Rng { state: if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed } }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn u64_in(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi, "u64_in: lo > hi");
        let span = hi - lo;
        if span == u64::MAX {
            return self.next_u64();
        }
        lo + self.next_u64() % (span + 1)
    }

    /// Uniform usize in `[lo, hi)` — the common indexing form.
    pub fn index(&mut self, len: usize) -> usize {
        assert!(len > 0, "index: empty range");
        (self.u64_in(0, len as u64 - 1)) as usize
    }

    pub fn i64_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo.wrapping_add(self.u64_in(0, (hi - lo) as u64) as i64)
    }

    /// Uniform float in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform float in `[lo, hi)`.
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Bernoulli with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.index(i + 1);
            v.swap(i, j);
        }
        v
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample_distinct: k > n");
        // Partial Fisher–Yates: O(n) memory, O(k) swaps.
        let mut v: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.index(n - i);
            v.swap(i, j);
        }
        v.truncate(k);
        v
    }

    /// Pick one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }
}

/// Run `prop` against `cases` independent RNGs (seeds 1..=cases).
///
/// Panics (re-raising the property's panic) with the failing seed in the
/// message so the case can be replayed with `Rng::new(seed)`.
pub fn forall<F: Fn(&mut Rng)>(cases: u64, prop: F) {
    for seed in 1..=cases {
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

/// Skip guard for PJRT-dependent integration tests: artifacts are
/// genuinely unavailable when the crate was built with the stub backend
/// (no `pjrt` feature) or when `make artifacts` has not produced the AOT
/// HLO files.  Returns `false` with a printed reason so tests return
/// early instead of failing; the suite runs in full on a PJRT-enabled
/// checkout.
pub fn pjrt_artifacts_ready(artifact_dir: &std::path::Path) -> bool {
    if !cfg!(feature = "pjrt") {
        eprintln!("skip: built without the `pjrt` feature (stub backend)");
        return false;
    }
    if !artifact_dir.join("manifest.json").exists() {
        eprintln!("skip: PJRT artifacts not built (run `make artifacts`)");
        return false;
    }
    true
}

/// The `gcn_layer_small` test binding the serving tests share (batch 16,
/// sample 4, feature 64, hidden 32, table 64) — the same shape the AOT
/// test artifact is built with.  Replaces the copy-pasted inline manifest
/// fixture the leader / semi / sharded-serving tests used to carry.
pub fn gcn_layer_binding() -> crate::coordinator::GcnLayerBinding {
    let doc = r#"{"version": 1, "artifacts": [
        {"name": "gcn_layer_small", "file": "f",
         "inputs": [], "outputs": [],
         "config": {"batch": 16, "sample": 4, "feature": 64,
                    "hidden": 32, "table": 64}}]}"#;
    let m = crate::runtime::Manifest::parse(std::path::Path::new("/fixture"), doc)
        .expect("fixture manifest parses");
    crate::coordinator::GcnLayerBinding::from_spec(
        m.get("gcn_layer_small").expect("fixture artifact exists"),
    )
    .expect("fixture binding is complete")
}

/// Assert two floats agree to a relative tolerance (absolute near zero).
#[track_caller]
pub fn assert_close(got: f64, want: f64, rtol: f64) {
    let denom = want.abs().max(1e-30);
    let rel = (got - want).abs() / denom;
    assert!(
        rel <= rtol || (got - want).abs() < 1e-30,
        "assert_close failed: got {got}, want {want} (rel err {rel:.3e} > rtol {rtol:.1e})"
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_zero_seed_works() {
        let mut r = Rng::new(0);
        // Must not be stuck at zero.
        assert_ne!(r.next_u64(), r.next_u64());
    }

    #[test]
    fn u64_in_respects_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let v = r.u64_in(10, 20);
            assert!((10..=20).contains(&v));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(2);
        let mut sum = 0.0;
        for _ in 0..4000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 4000.0;
        assert!((mean - 0.5).abs() < 0.03, "mean {mean} too far from 0.5");
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(3);
        let n = 8000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.06, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn permutation_is_a_bijection() {
        let mut r = Rng::new(4);
        let p = r.permutation(50);
        let mut seen = vec![false; 50];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn sample_distinct_is_distinct() {
        let mut r = Rng::new(5);
        for _ in 0..50 {
            let k = r.index(10) + 1;
            let s = r.sample_distinct(30, k);
            assert_eq!(s.len(), k);
            let mut sorted = s.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted.len(), k, "duplicates in sample");
        }
    }

    #[test]
    fn forall_reports_seed() {
        let r = std::panic::catch_unwind(|| {
            forall(10, |rng| {
                // Fails when the first draw is even — some seed will hit it.
                assert!(rng.next_u64() % 2 == 1, "even draw");
            });
        });
        let msg = match r {
            Err(p) => p.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("seed"), "missing seed in: {msg}");
    }

    #[test]
    fn assert_close_accepts_and_rejects() {
        assert_close(100.0, 100.4, 0.01);
        let r = std::panic::catch_unwind(|| assert_close(1.0, 2.0, 0.01));
        assert!(r.is_err());
    }
}
