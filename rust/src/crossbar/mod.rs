//! Architecture-level crossbar arrays (paper Fig. 2(b)/(c)).
//!
//! Each array is both *functional* (bit-exact fixed-point MVM / CAM ops,
//! matching the Layer-1 Pallas kernels and their jnp oracles) and a
//! *timing/energy roll-up* composed from the `device` component models.
//!
//! DESIGN.md: §3 (architecture level).

mod cam;
mod mvm;

pub use cam::CamCrossbar;
pub use mvm::{MvmCrossbar, DENSE_WORD_THRESHOLD};
