//! Resistive MVM crossbar (paper Fig. 2(b)).
//!
//! Functional model: weights are programmed as signed conductance levels
//! (`cell_bits`), inputs stream as unsigned codes (`input_bits`), and the
//! evaluation is bit-serial — one input bit-plane per pass, per-column
//! analog accumulation, ADC clip to `adc_bits`, Shift & Add recombination.
//! This matches `python/compile/kernels/mvm_crossbar.py` bit-exactly (see
//! `tests/parity_kernel.rs` fixtures).
//!
//! Timing/energy model: one *pass* = DAC drive + array settle + Sample&Hold
//! + (cols / ADCs) sequential conversions + Shift&Add, composed from the
//! `device` components.

use crate::config::{CrossbarGeometry, DeviceParams};
use crate::device::{Adc, Dac, RramCell, SampleHold, ShiftAdd};
use crate::error::{Error, Result};
use crate::units::{Energy, Power, Time};

/// One resistive MVM crossbar array.
#[derive(Debug, Clone)]
pub struct MvmCrossbar {
    geometry: CrossbarGeometry,
    device: DeviceParams,
    /// Programmed conductance levels, row-major `[rows][cols]`, signed.
    weights: Vec<i32>,
}

impl MvmCrossbar {
    pub fn new(geometry: CrossbarGeometry, device: DeviceParams) -> Result<MvmCrossbar> {
        geometry.validate()?;
        device.validate()?;
        Ok(MvmCrossbar {
            weights: vec![0; geometry.cells()],
            geometry,
            device,
        })
    }

    pub fn geometry(&self) -> &CrossbarGeometry {
        &self.geometry
    }

    /// Signed range of one cell: `[-2^(b-1), 2^(b-1) - 1]`.
    pub fn weight_range(&self) -> (i32, i32) {
        let half = 1i64 << (self.geometry.cell_bits - 1);
        (-(half as i32), (half - 1) as i32)
    }

    /// Program the full array (row-major `rows × cols`).
    pub fn program(&mut self, weights: &[i32]) -> Result<()> {
        if weights.len() != self.geometry.cells() {
            return Err(Error::Hardware(format!(
                "program: expected {} weights, got {}",
                self.geometry.cells(),
                weights.len()
            )));
        }
        let (lo, hi) = self.weight_range();
        if let Some(w) = weights.iter().find(|w| **w < lo || **w > hi) {
            return Err(Error::Hardware(format!(
                "weight {w} outside conductance range [{lo}, {hi}]"
            )));
        }
        self.weights.copy_from_slice(weights);
        Ok(())
    }

    /// Program a sub-tile starting at row 0 / col 0, zero elsewhere.
    pub fn program_tile(&mut self, tile: &[i32], rows: usize, cols: usize) -> Result<()> {
        if rows > self.geometry.rows || cols > self.geometry.cols {
            return Err(Error::Hardware(format!(
                "tile {rows}x{cols} exceeds array {}x{}",
                self.geometry.rows, self.geometry.cols
            )));
        }
        if tile.len() != rows * cols {
            return Err(Error::Hardware("tile shape mismatch".into()));
        }
        self.weights.fill(0);
        let (lo, hi) = self.weight_range();
        for r in 0..rows {
            for c in 0..cols {
                let w = tile[r * cols + c];
                if w < lo || w > hi {
                    return Err(Error::Hardware(format!(
                        "weight {w} outside conductance range [{lo}, {hi}]"
                    )));
                }
                self.weights[r * self.geometry.cols + c] = w;
            }
        }
        Ok(())
    }

    /// Bit-serial evaluate: `out[c] = Σ_b 2^b · clip(Σ_r bit_b(x[r]) · G[r][c])`.
    ///
    /// `input` must contain unsigned codes < 2^input_bits, one per row.
    /// The ADC clip applies per column per bit-plane — the analog boundary.
    pub fn evaluate(&self, input: &[u32]) -> Result<Vec<i64>> {
        if input.len() != self.geometry.rows {
            return Err(Error::Hardware(format!(
                "evaluate: expected {} inputs, got {}",
                self.geometry.rows,
                input.len()
            )));
        }
        let max_code = if self.geometry.input_bits >= 32 {
            u32::MAX
        } else {
            (1u32 << self.geometry.input_bits) - 1
        };
        if let Some(x) = input.iter().find(|x| **x > max_code) {
            return Err(Error::Hardware(format!(
                "input code {x} exceeds {}-bit DAC range",
                self.geometry.input_bits
            )));
        }
        let cols = self.geometry.cols;
        let lo = -(1i64 << (self.geometry.adc_bits - 1));
        let hi = (1i64 << (self.geometry.adc_bits - 1)) - 1;
        let mut out = vec![0i64; cols];
        let mut plane_sum = vec![0i64; cols];
        for b in 0..self.geometry.input_bits {
            plane_sum.fill(0);
            for (r, &x) in input.iter().enumerate() {
                if (x >> b) & 1 == 1 {
                    let row = &self.weights[r * cols..(r + 1) * cols];
                    for (c, &w) in row.iter().enumerate() {
                        plane_sum[c] += w as i64;
                    }
                }
            }
            for c in 0..cols {
                // Sample & hold + ADC: clip to converter range.
                let clipped = plane_sum[c].clamp(lo, hi);
                // Shift & add.
                out[c] += clipped << b;
            }
        }
        Ok(out)
    }

    /// Latency of one evaluate pass (one bit-plane).
    pub fn pass_latency(&self) -> Time {
        let d = &self.device;
        Dac::new(d).latency()
            + d.array_settle
            + SampleHold::new(d).latency()
            + Adc::new(d).latency() * self.geometry.adc_rounds() as f64
            + ShiftAdd::new(d).latency()
    }

    /// Latency of a full `input_bits`-deep evaluation.
    pub fn mvm_latency(&self) -> Time {
        self.pass_latency() * self.geometry.input_bits as f64
    }

    /// Dynamic energy of one evaluate pass.
    ///
    /// Cell read energy scales with word-line length (`rows / 512`): longer
    /// lines mean larger parasitics per access — this is what lets the
    /// small feature-extraction array (128 rows) run cheaper per cell than
    /// the 512-row aggregation array.
    pub fn pass_energy(&self) -> Energy {
        let d = &self.device;
        let line_factor = self.geometry.rows as f64 / 512.0;
        let cells = self.geometry.cells() as f64;
        Dac::new(d).energy()
            + SampleHold::new(d).energy()
            + ShiftAdd::new(d).energy()
            + Adc::new(d).energy() * self.geometry.adc_rounds() as f64
            + RramCell::new(d).read_energy() * cells * line_factor
    }

    /// Static leakage of the array.
    pub fn leakage(&self) -> Power {
        RramCell::new(&self.device).leakage() * self.geometry.cells() as f64
    }

    /// Average dynamic power while continuously evaluating.
    pub fn active_power(&self) -> Power {
        self.pass_energy() / self.pass_latency()
    }

    /// Write (programming) latency for the full array, one row at a time —
    /// used by the double-buffering overlap model.
    pub fn program_latency(&self) -> Time {
        // RRAM write pulse ~50 ns per row (documented substitute constant).
        Time::ns(50.0) * self.geometry.rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceParams;
    use crate::testing::{forall, Rng};

    fn xbar(rows: usize, cols: usize) -> MvmCrossbar {
        MvmCrossbar::new(CrossbarGeometry::new(rows, cols), DeviceParams::default_45nm()).unwrap()
    }

    /// Reference: plain integer matmul (lossless ADC ⇒ identical).
    fn matmul_ref(input: &[u32], weights: &[i32], rows: usize, cols: usize) -> Vec<i64> {
        let mut out = vec![0i64; cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c] += input[r] as i64 * weights[r * cols + c] as i64;
            }
        }
        out
    }

    #[test]
    fn lossless_adc_equals_matmul() {
        forall(24, |rng: &mut Rng| {
            let rows = rng.index(40) + 1;
            let cols = rng.index(24) + 1;
            let mut g = CrossbarGeometry::new(rows, cols);
            g.adc_bits = 24; // lossless for these sizes
            let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
            let weights: Vec<i32> = (0..rows * cols).map(|_| rng.i64_in(-8, 7) as i32).collect();
            xb.program(&weights).unwrap();
            let input: Vec<u32> = (0..rows).map(|_| rng.u64_in(0, 255) as u32).collect();
            let got = xb.evaluate(&input).unwrap();
            assert_eq!(got, matmul_ref(&input, &weights, rows, cols));
        });
    }

    #[test]
    fn adc_clipping_bounds_partial_sums() {
        // All-ones everywhere: per-plane column sum = rows = 64, clipped to
        // adc range [-8, 7] with adc_bits=4 ⇒ every plane contributes 7.
        let mut g = CrossbarGeometry::new(64, 4);
        g.adc_bits = 4;
        g.input_bits = 8;
        let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
        xb.program(&vec![1; 64 * 4]).unwrap();
        let out = xb.evaluate(&vec![255u32; 64]).unwrap();
        let want = (0..8).map(|b| 7i64 << b).sum::<i64>();
        assert!(out.iter().all(|&o| o == want), "{out:?} != {want}");
    }

    #[test]
    fn clipping_is_per_bitplane_not_per_total() {
        // One active bit-plane (inputs = 1): sums clip at plane level.
        let mut g = CrossbarGeometry::new(32, 1);
        g.adc_bits = 4;
        g.input_bits = 1;
        let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
        xb.program(&vec![7; 32]).unwrap();
        let out = xb.evaluate(&vec![1u32; 32]).unwrap();
        assert_eq!(out[0], 7); // 32*7=224 clipped to 7
    }

    #[test]
    fn negative_weights_accumulate() {
        let mut xb = xbar(3, 2);
        xb.program(&[-8, 7, -1, 2, 3, -4]).unwrap();
        let out = xb.evaluate(&[1, 2, 3]).unwrap();
        assert_eq!(out, matmul_ref(&[1, 2, 3], &[-8, 7, -1, 2, 3, -4], 3, 2));
    }

    #[test]
    fn program_tile_zero_pads() {
        let mut xb = xbar(4, 4);
        xb.program_tile(&[1, 2, 3, 4], 2, 2).unwrap();
        let out = xb.evaluate(&[1, 1, 1, 1]).unwrap();
        assert_eq!(out, vec![4, 6, 0, 0]);
    }

    #[test]
    fn rejects_bad_inputs() {
        let mut xb = xbar(4, 4);
        assert!(xb.program(&[0; 3]).is_err());
        assert!(xb.program(&[100; 16]).is_err()); // out of 4-bit range
        assert!(xb.evaluate(&[0; 3]).is_err()); // wrong length
        assert!(xb.evaluate(&[256, 0, 0, 0]).is_err()); // exceeds 8-bit DAC
        assert!(xb.program_tile(&[1; 25], 5, 5).is_err()); // tile too big
    }

    #[test]
    fn weight_range_follows_cell_bits() {
        let mut g = CrossbarGeometry::new(2, 2);
        g.cell_bits = 2;
        let xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
        assert_eq!(xb.weight_range(), (-2, 1));
    }

    #[test]
    fn aggregation_pass_latency_matches_calibration() {
        // 512×512 with 8 ADCs: 1 + 13 + 1 + 64·1.28 + 2.18 = 99.10 ns.
        let xb = xbar(512, 512);
        crate::testing::assert_close(xb.pass_latency().as_ns(), 99.10, 0.001);
    }

    #[test]
    fn fe_pass_latency_matches_calibration() {
        // 128×128 with 32 ADCs: 1 + 13 + 1 + 4·1.28 + 2.18 = 22.30 ns.
        let mut g = CrossbarGeometry::new(128, 128);
        g.adcs = 32;
        let xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
        crate::testing::assert_close(xb.pass_latency().as_ns(), 22.30, 0.001);
    }

    #[test]
    fn energy_scales_with_array_size() {
        let big = xbar(512, 512);
        let small = xbar(128, 128);
        assert!(big.pass_energy() > small.pass_energy());
        assert!(big.leakage() > small.leakage());
        assert!(big.active_power().as_mw() > 0.0);
    }

    #[test]
    fn mvm_latency_is_bits_times_pass() {
        let xb = xbar(64, 64);
        let ratio = xb.mvm_latency() / xb.pass_latency();
        crate::testing::assert_close(ratio, 8.0, 1e-12);
    }
}
