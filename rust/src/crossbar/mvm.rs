//! Resistive MVM crossbar (paper Fig. 2(b)).
//!
//! Functional model: weights are programmed as signed conductance levels
//! (`cell_bits`), inputs stream as unsigned codes (`input_bits`), and the
//! evaluation is bit-serial — one input bit-plane per pass, per-column
//! analog accumulation, ADC clip to `adc_bits`, Shift & Add recombination.
//! This matches `python/compile/kernels/mvm_crossbar.py` bit-exactly (see
//! `tests/parity_kernel.rs` fixtures).
//!
//! Timing/energy model: one *pass* = DAC drive + array settle + Sample&Hold
//! + (cols / ADCs) sequential conversions + Shift&Add, composed from the
//! `device` components.
//!
//! DESIGN.md: §3 (architecture level); §8 (the fast evaluate paths).

use crate::config::{CrossbarGeometry, DeviceParams};
use crate::device::{Adc, Dac, RramCell, SampleHold, ShiftAdd};
use crate::error::{Error, Result};
use crate::units::{Energy, Power, Time};

/// Popcount at or above which a 64-bit mask word takes the dense
/// row-slab path of [`MvmCrossbar::accumulate_rows`] (below it, the
/// sparse `bits &= bits - 1` walk wins — see DESIGN.md §15).  Public so
/// the differential fuzz harness can force masks onto both sides of the
/// dispatch boundary.
pub const DENSE_WORD_THRESHOLD: u32 = 32;

/// Lane width of the unrolled inner loops (§15): fixed-trip-count
/// chunks the compiler can keep in registers / autovectorize.  i64
/// integer accumulators make any reassociation across lanes exact, so
/// every lane path stays bit-identical to the scalar reference.
const LANES: usize = 8;

/// `out[c] += row[c]`, LANES-wide unrolled over the common prefix
/// (`out.len() == row.len()` by construction at every call site).
#[inline]
fn add_row_lanes(out: &mut [i64], row: &[i32]) {
    let mut o = out.chunks_exact_mut(LANES);
    let mut r = row.chunks_exact(LANES);
    for (oc, rc) in (&mut o).zip(&mut r) {
        for (ov, &rv) in oc.iter_mut().zip(rc) {
            *ov += rv as i64;
        }
    }
    for (ov, &rv) in o.into_remainder().iter_mut().zip(r.remainder()) {
        *ov += rv as i64;
    }
}

/// `out[c] += x * row[c]`, the scaled (fused multi-bit) lane variant.
#[inline]
fn add_row_scaled_lanes(out: &mut [i64], row: &[i32], x: i64) {
    let mut o = out.chunks_exact_mut(LANES);
    let mut r = row.chunks_exact(LANES);
    for (oc, rc) in (&mut o).zip(&mut r) {
        for (ov, &rv) in oc.iter_mut().zip(rc) {
            *ov += x * rv as i64;
        }
    }
    for (ov, &rv) in o.into_remainder().iter_mut().zip(r.remainder()) {
        *ov += x * rv as i64;
    }
}

/// One resistive MVM crossbar array.
#[derive(Debug, Clone)]
pub struct MvmCrossbar {
    geometry: CrossbarGeometry,
    device: DeviceParams,
    /// Programmed conductance levels, row-major `[rows][cols]`, signed.
    weights: Vec<i32>,
    /// Largest achievable per-bit-plane column sum for the programmed
    /// weights (max over columns of the column's positive-weight sum).
    plane_max: i64,
    /// Smallest achievable per-bit-plane column sum (min over columns of
    /// the column's negative-weight sum).
    plane_min: i64,
}

impl MvmCrossbar {
    pub fn new(geometry: CrossbarGeometry, device: DeviceParams) -> Result<MvmCrossbar> {
        geometry.validate()?;
        device.validate()?;
        Ok(MvmCrossbar {
            weights: vec![0; geometry.cells()],
            geometry,
            device,
            plane_max: 0,
            plane_min: 0,
        })
    }

    pub fn geometry(&self) -> &CrossbarGeometry {
        &self.geometry
    }

    /// Signed range of one cell: `[-2^(b-1), 2^(b-1) - 1]`.
    pub fn weight_range(&self) -> (i32, i32) {
        let half = 1i64 << (self.geometry.cell_bits - 1);
        (-(half as i32), (half - 1) as i32)
    }

    /// Program the full array (row-major `rows × cols`).
    pub fn program(&mut self, weights: &[i32]) -> Result<()> {
        if weights.len() != self.geometry.cells() {
            return Err(Error::Hardware(format!(
                "program: expected {} weights, got {}",
                self.geometry.cells(),
                weights.len()
            )));
        }
        let (lo, hi) = self.weight_range();
        if let Some(w) = weights.iter().find(|w| **w < lo || **w > hi) {
            return Err(Error::Hardware(format!(
                "weight {w} outside conductance range [{lo}, {hi}]"
            )));
        }
        self.weights.copy_from_slice(weights);
        self.recompute_plane_bounds();
        Ok(())
    }

    /// Program a sub-tile starting at row 0 / col 0, zero elsewhere.
    pub fn program_tile(&mut self, tile: &[i32], rows: usize, cols: usize) -> Result<()> {
        if rows > self.geometry.rows || cols > self.geometry.cols {
            return Err(Error::Hardware(format!(
                "tile {rows}x{cols} exceeds array {}x{}",
                self.geometry.rows, self.geometry.cols
            )));
        }
        if tile.len() != rows * cols {
            return Err(Error::Hardware("tile shape mismatch".into()));
        }
        // Validate before touching the array: a failed program must not
        // leave partially-written weights (or stale plane bounds — the
        // clip-free dispatch depends on them matching the array).
        let (lo, hi) = self.weight_range();
        if let Some(w) = tile.iter().find(|w| **w < lo || **w > hi) {
            return Err(Error::Hardware(format!(
                "weight {w} outside conductance range [{lo}, {hi}]"
            )));
        }
        self.weights.fill(0);
        for r in 0..rows {
            self.weights[r * self.geometry.cols..r * self.geometry.cols + cols]
                .copy_from_slice(&tile[r * cols..(r + 1) * cols]);
        }
        self.recompute_plane_bounds();
        Ok(())
    }

    /// True when `tile` (row-major `rows × cols`) equals the array's
    /// top-left block.  On an array whose state came from `program_tile`
    /// (or is still the all-zero initial state), this is exactly "would
    /// `program_tile(tile, rows, cols)` be a no-op" — cells outside the
    /// block are already zero and are deliberately not re-checked.  Lets
    /// the cores' program-once caches test residency against the array
    /// itself (the ground truth) instead of keeping a second copy of the
    /// tile.  Callers mixing in full-array `program` writes must not use
    /// this as a `program_tile` equivalence check.
    pub fn tile_resident(&self, tile: &[i32], rows: usize, cols: usize) -> bool {
        if rows > self.geometry.rows || cols > self.geometry.cols || tile.len() != rows * cols {
            return false;
        }
        let stride = self.geometry.cols;
        (0..rows).all(|r| {
            self.weights[r * stride..r * stride + cols] == tile[r * cols..(r + 1) * cols]
        })
    }

    /// Refresh `plane_max`/`plane_min` after (re)programming: the extreme
    /// per-plane column sums any activation subset can produce.  One
    /// row-major pass (sequential loads) accumulating per-column
    /// positive/negative sums, then a max/min reduction.
    fn recompute_plane_bounds(&mut self) {
        let cols = self.geometry.cols;
        if cols == 0 {
            self.plane_max = 0;
            self.plane_min = 0;
            return;
        }
        let mut pos = vec![0i64; cols];
        let mut neg = vec![0i64; cols];
        for row in self.weights.chunks_exact(cols) {
            for ((p, n), &w) in pos.iter_mut().zip(neg.iter_mut()).zip(row.iter()) {
                let w = w as i64;
                if w > 0 {
                    *p += w;
                } else {
                    *n += w;
                }
            }
        }
        self.plane_max = pos.into_iter().max().unwrap_or(0);
        self.plane_min = neg.into_iter().min().unwrap_or(0);
    }

    /// ADC converter range `[lo, hi]` (shift capped at 62 bits — beyond
    /// that the converter is lossless for any representable plane sum).
    fn adc_range(&self) -> (i64, i64) {
        let b = self.geometry.adc_bits.min(62);
        (-(1i64 << (b - 1)), (1i64 << (b - 1)) - 1)
    }

    /// True when no achievable bit-plane column sum can leave the ADC
    /// range for the currently programmed weights — `clip(x) == x` for
    /// every reachable partial sum, so the bit-serial recombination
    /// collapses to an exact integer matmul (the fused fast path).
    pub fn clip_free(&self) -> bool {
        let (lo, hi) = self.adc_range();
        self.plane_max <= hi && self.plane_min >= lo
    }

    /// Bit-serial evaluate: `out[c] = Σ_b 2^b · clip(Σ_r bit_b(x[r]) · G[r][c])`.
    ///
    /// `input` must contain unsigned codes < 2^input_bits, one per row.
    /// The ADC clip applies per column per bit-plane — the analog boundary.
    ///
    /// Allocating wrapper over [`Self::evaluate_into`]; both dispatch to
    /// the fast paths and are bit-identical to
    /// [`Self::evaluate_reference`] (property-tested below).
    pub fn evaluate(&self, input: &[u32]) -> Result<Vec<i64>> {
        let mut out = vec![0i64; self.geometry.cols];
        self.evaluate_into(input, &mut out)?;
        Ok(out)
    }

    /// Evaluate into the caller's buffer (`out.len() == cols`).
    ///
    /// Dispatch: binary inputs take the single-plane sum+clamp path
    /// (exact — planes ≥ 1 see zero bits and contribute `clip(0) = 0`);
    /// otherwise, when the programmed weights provably cannot clip
    /// ([`Self::clip_free`]), the plane loop collapses to one fused
    /// multiply-accumulate; the general (clipping, multi-bit) case falls
    /// back to the bit-serial reference.  The two fast paths are
    /// allocation-free; only the clipping fallback allocates its plane
    /// scratch.
    pub fn evaluate_into(&self, input: &[u32], out: &mut [i64]) -> Result<()> {
        self.check_input(input)?;
        if out.len() != self.geometry.cols {
            return Err(Error::Hardware(format!(
                "evaluate: expected {} outputs, got {}",
                self.geometry.cols,
                out.len()
            )));
        }
        if input.iter().all(|&x| x <= 1) {
            self.evaluate_binary(input, out);
        } else if self.clip_free() {
            self.evaluate_fused(input, out);
        } else {
            self.reference_into(input, out);
        }
        Ok(())
    }

    /// The seed's bit-serial plane loop, kept verbatim as the semantic
    /// reference for the fast paths (and as the perfbench baseline).
    pub fn evaluate_reference(&self, input: &[u32]) -> Result<Vec<i64>> {
        self.check_input(input)?;
        let mut out = vec![0i64; self.geometry.cols];
        self.reference_into(input, &mut out);
        Ok(out)
    }

    /// Binary-activation evaluate over a packed row mask (`bit r` of
    /// `mask[r / 64]` selects row `r`): sum the selected rows per column
    /// and clamp once to the ADC range — exactly `evaluate` with 1-bit
    /// DAC codes, without materializing the codes.  `out.len()` may be
    /// ≤ `cols`; only the leading columns are produced (a programmed
    /// sub-tile's column group).  Bits at rows ≥ `rows` must be zero.
    pub fn accumulate_rows(&self, mask: &[u64], out: &mut [i64]) -> Result<()> {
        let rows = self.geometry.rows;
        let cols = self.geometry.cols;
        if mask.len() != rows.div_ceil(64) {
            return Err(Error::Hardware(format!(
                "activation mask has {} words, {} rows need {}",
                mask.len(),
                rows,
                rows.div_ceil(64)
            )));
        }
        if out.len() > cols {
            return Err(Error::Hardware(format!(
                "{} outputs exceed {} columns",
                out.len(),
                cols
            )));
        }
        if rows % 64 != 0 && mask[mask.len() - 1] >> (rows % 64) != 0 {
            return Err(Error::Hardware(format!(
                "activation mask selects rows beyond the {rows}-row array"
            )));
        }
        out.fill(0);
        for (w, &word) in mask.iter().enumerate() {
            if word == 0 {
                continue;
            }
            if word.count_ones() >= DENSE_WORD_THRESHOLD {
                self.accumulate_word_dense(w, word, out);
            } else {
                self.accumulate_word_sparse(w, word, out);
            }
        }
        let (lo, hi) = self.adc_range();
        for o in out.iter_mut() {
            *o = (*o).clamp(lo, hi);
        }
        Ok(())
    }

    /// Sparse side of the [`DENSE_WORD_THRESHOLD`] dispatch: walk the
    /// word's set bits (`bits &= bits - 1`) and add each selected row
    /// with the lane-unrolled kernel.
    fn accumulate_word_sparse(&self, w: usize, word: u64, out: &mut [i64]) {
        let cols = self.geometry.cols;
        let k = out.len();
        let mut bits = word;
        while bits != 0 {
            let r = w * 64 + bits.trailing_zeros() as usize;
            bits &= bits - 1;
            add_row_lanes(out, &self.weights[r * cols..r * cols + k]);
        }
    }

    /// Dense side of the dispatch: the word selects most of its ≤64-row
    /// slab, so column-block the adds instead — one `[i64; LANES]`
    /// register accumulator per column block, streaming every selected
    /// row of the slab through it before the block is written back to
    /// `out` once.  Reassociates the per-column sum across rows, which
    /// is exact for i64 integer adds (DESIGN.md §15).
    fn accumulate_word_dense(&self, w: usize, word: u64, out: &mut [i64]) {
        let cols = self.geometry.cols;
        let base = w * 64;
        let slab_rows = (self.geometry.rows - base).min(64);
        let k = out.len();
        let mut c0 = 0;
        while c0 < k {
            let width = LANES.min(k - c0);
            let mut acc = [0i64; LANES];
            for dr in 0..slab_rows {
                if (word >> dr) & 1 == 0 {
                    continue;
                }
                let at = (base + dr) * cols + c0;
                for (a, &wt) in acc.iter_mut().zip(&self.weights[at..at + width]) {
                    *a += wt as i64;
                }
            }
            for (o, &a) in out[c0..c0 + width].iter_mut().zip(&acc) {
                *o += a;
            }
            c0 += width;
        }
    }

    /// Shared input validation (arity + DAC range).
    fn check_input(&self, input: &[u32]) -> Result<()> {
        if input.len() != self.geometry.rows {
            return Err(Error::Hardware(format!(
                "evaluate: expected {} inputs, got {}",
                self.geometry.rows,
                input.len()
            )));
        }
        let max_code = if self.geometry.input_bits >= 32 {
            u32::MAX
        } else {
            (1u32 << self.geometry.input_bits) - 1
        };
        if let Some(x) = input.iter().find(|x| **x > max_code) {
            return Err(Error::Hardware(format!(
                "input code {x} exceeds {}-bit DAC range",
                self.geometry.input_bits
            )));
        }
        Ok(())
    }

    /// Single-plane path for binary inputs: only bit-plane 0 carries
    /// activations, so one row sweep + one clamp reproduces the full
    /// bit-serial result.  Row-major on purpose — the full weight
    /// matrix does not fit L1, so each active row streams through the
    /// lane-unrolled add once (§15).
    fn evaluate_binary(&self, input: &[u32], out: &mut [i64]) {
        let cols = self.geometry.cols;
        out.fill(0);
        for (r, &x) in input.iter().enumerate() {
            if x == 0 {
                continue;
            }
            add_row_lanes(out, &self.weights[r * cols..(r + 1) * cols]);
        }
        let (lo, hi) = self.adc_range();
        for o in out.iter_mut() {
            *o = (*o).clamp(lo, hi);
        }
    }

    /// Clip-free fused path: with no reachable plane sum outside the ADC
    /// range, `Σ_b 2^b·Σ_r bit_b(x_r)·G = Σ_r x_r·G` exactly.  Same
    /// row-major lane treatment as the binary path.
    fn evaluate_fused(&self, input: &[u32], out: &mut [i64]) {
        let cols = self.geometry.cols;
        out.fill(0);
        for (r, &x) in input.iter().enumerate() {
            if x == 0 {
                continue;
            }
            add_row_scaled_lanes(out, &self.weights[r * cols..(r + 1) * cols], x as i64);
        }
    }

    /// The bit-serial plane loop (allocates its plane scratch).  Serves
    /// as the semantic reference and as the dispatched fallback for the
    /// clipping multi-bit regime, where no shortcut is exact.
    fn reference_into(&self, input: &[u32], out: &mut [i64]) {
        let cols = self.geometry.cols;
        let (lo, hi) = self.adc_range();
        out.fill(0);
        let mut plane_sum = vec![0i64; cols];
        for b in 0..self.geometry.input_bits {
            plane_sum.fill(0);
            for (r, &x) in input.iter().enumerate() {
                if (x >> b) & 1 == 1 {
                    let row = &self.weights[r * cols..(r + 1) * cols];
                    for (c, &w) in row.iter().enumerate() {
                        plane_sum[c] += w as i64;
                    }
                }
            }
            for c in 0..cols {
                // Sample & hold + ADC: clip to converter range; Shift & add.
                out[c] += plane_sum[c].clamp(lo, hi) << b;
            }
        }
    }

    /// Latency of one evaluate pass (one bit-plane).
    pub fn pass_latency(&self) -> Time {
        let d = &self.device;
        Dac::new(d).latency()
            + d.array_settle
            + SampleHold::new(d).latency()
            + Adc::new(d).latency() * self.geometry.adc_rounds() as f64
            + ShiftAdd::new(d).latency()
    }

    /// Latency of a full `input_bits`-deep evaluation.
    pub fn mvm_latency(&self) -> Time {
        self.pass_latency() * self.geometry.input_bits as f64
    }

    /// Dynamic energy of one evaluate pass.
    ///
    /// Cell read energy scales with word-line length (`rows / 512`): longer
    /// lines mean larger parasitics per access — this is what lets the
    /// small feature-extraction array (128 rows) run cheaper per cell than
    /// the 512-row aggregation array.
    pub fn pass_energy(&self) -> Energy {
        let d = &self.device;
        let line_factor = self.geometry.rows as f64 / 512.0;
        let cells = self.geometry.cells() as f64;
        Dac::new(d).energy()
            + SampleHold::new(d).energy()
            + ShiftAdd::new(d).energy()
            + Adc::new(d).energy() * self.geometry.adc_rounds() as f64
            + RramCell::new(d).read_energy() * cells * line_factor
    }

    /// Static leakage of the array.
    pub fn leakage(&self) -> Power {
        RramCell::new(&self.device).leakage() * self.geometry.cells() as f64
    }

    /// Average dynamic power while continuously evaluating.
    pub fn active_power(&self) -> Power {
        self.pass_energy() / self.pass_latency()
    }

    /// Write (programming) latency for the full array, one row at a time —
    /// used by the double-buffering overlap model.
    pub fn program_latency(&self) -> Time {
        // RRAM write pulse ~50 ns per row (documented substitute constant).
        Time::ns(50.0) * self.geometry.rows as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceParams;
    use crate::testing::{forall, Rng};

    fn xbar(rows: usize, cols: usize) -> MvmCrossbar {
        MvmCrossbar::new(CrossbarGeometry::new(rows, cols), DeviceParams::default_45nm()).unwrap()
    }

    /// Reference: plain integer matmul (lossless ADC ⇒ identical).
    fn matmul_ref(input: &[u32], weights: &[i32], rows: usize, cols: usize) -> Vec<i64> {
        let mut out = vec![0i64; cols];
        for r in 0..rows {
            for c in 0..cols {
                out[c] += input[r] as i64 * weights[r * cols + c] as i64;
            }
        }
        out
    }

    #[test]
    fn lossless_adc_equals_matmul() {
        forall(24, |rng: &mut Rng| {
            let rows = rng.index(40) + 1;
            let cols = rng.index(24) + 1;
            let mut g = CrossbarGeometry::new(rows, cols);
            g.adc_bits = 24; // lossless for these sizes
            let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
            let weights: Vec<i32> = (0..rows * cols).map(|_| rng.i64_in(-8, 7) as i32).collect();
            xb.program(&weights).unwrap();
            let input: Vec<u32> = (0..rows).map(|_| rng.u64_in(0, 255) as u32).collect();
            let got = xb.evaluate(&input).unwrap();
            assert_eq!(got, matmul_ref(&input, &weights, rows, cols));
        });
    }

    #[test]
    fn adc_clipping_bounds_partial_sums() {
        // All-ones everywhere: per-plane column sum = rows = 64, clipped to
        // adc range [-8, 7] with adc_bits=4 ⇒ every plane contributes 7.
        let mut g = CrossbarGeometry::new(64, 4);
        g.adc_bits = 4;
        g.input_bits = 8;
        let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
        xb.program(&vec![1; 64 * 4]).unwrap();
        let out = xb.evaluate(&vec![255u32; 64]).unwrap();
        let want = (0..8).map(|b| 7i64 << b).sum::<i64>();
        assert!(out.iter().all(|&o| o == want), "{out:?} != {want}");
    }

    #[test]
    fn clipping_is_per_bitplane_not_per_total() {
        // One active bit-plane (inputs = 1): sums clip at plane level.
        let mut g = CrossbarGeometry::new(32, 1);
        g.adc_bits = 4;
        g.input_bits = 1;
        let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
        xb.program(&vec![7; 32]).unwrap();
        let out = xb.evaluate(&vec![1u32; 32]).unwrap();
        assert_eq!(out[0], 7); // 32*7=224 clipped to 7
    }

    #[test]
    fn negative_weights_accumulate() {
        let mut xb = xbar(3, 2);
        xb.program(&[-8, 7, -1, 2, 3, -4]).unwrap();
        let out = xb.evaluate(&[1, 2, 3]).unwrap();
        assert_eq!(out, matmul_ref(&[1, 2, 3], &[-8, 7, -1, 2, 3, -4], 3, 2));
    }

    #[test]
    fn program_tile_zero_pads() {
        let mut xb = xbar(4, 4);
        xb.program_tile(&[1, 2, 3, 4], 2, 2).unwrap();
        let out = xb.evaluate(&[1, 1, 1, 1]).unwrap();
        assert_eq!(out, vec![4, 6, 0, 0]);
    }

    #[test]
    fn rejects_bad_inputs() {
        let mut xb = xbar(4, 4);
        assert!(xb.program(&[0; 3]).is_err());
        assert!(xb.program(&[100; 16]).is_err()); // out of 4-bit range
        assert!(xb.evaluate(&[0; 3]).is_err()); // wrong length
        assert!(xb.evaluate(&[256, 0, 0, 0]).is_err()); // exceeds 8-bit DAC
        assert!(xb.program_tile(&[1; 25], 5, 5).is_err()); // tile too big
    }

    #[test]
    fn weight_range_follows_cell_bits() {
        let mut g = CrossbarGeometry::new(2, 2);
        g.cell_bits = 2;
        let xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
        assert_eq!(xb.weight_range(), (-2, 1));
    }

    #[test]
    fn aggregation_pass_latency_matches_calibration() {
        // 512×512 with 8 ADCs: 1 + 13 + 1 + 64·1.28 + 2.18 = 99.10 ns.
        let xb = xbar(512, 512);
        crate::testing::assert_close(xb.pass_latency().as_ns(), 99.10, 0.001);
    }

    #[test]
    fn fe_pass_latency_matches_calibration() {
        // 128×128 with 32 ADCs: 1 + 13 + 1 + 4·1.28 + 2.18 = 22.30 ns.
        let mut g = CrossbarGeometry::new(128, 128);
        g.adcs = 32;
        let xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
        crate::testing::assert_close(xb.pass_latency().as_ns(), 22.30, 0.001);
    }

    #[test]
    fn energy_scales_with_array_size() {
        let big = xbar(512, 512);
        let small = xbar(128, 128);
        assert!(big.pass_energy() > small.pass_energy());
        assert!(big.leakage() > small.leakage());
        assert!(big.active_power().as_mw() > 0.0);
    }

    #[test]
    fn mvm_latency_is_bits_times_pass() {
        let xb = xbar(64, 64);
        let ratio = xb.mvm_latency() / xb.pass_latency();
        crate::testing::assert_close(ratio, 8.0, 1e-12);
    }

    /// Tentpole invariant: the dispatched fast paths (binary single-plane,
    /// clip-free fused, packed accumulate) are bit-identical to the seed
    /// bit-serial reference across random geometries, weights and inputs —
    /// in both the clipping and the clip-free regime.
    #[test]
    fn fast_paths_are_bit_identical_to_the_reference() {
        forall(48, |rng: &mut Rng| {
            let rows = rng.index(96) + 1;
            let cols = rng.index(48) + 1;
            let mut g = CrossbarGeometry::new(rows, cols);
            g.cell_bits = rng.u64_in(2, 5) as u32;
            g.adc_bits = rng.u64_in(3, 16) as u32;
            g.input_bits = rng.u64_in(1, 8) as u32;
            let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
            let (lo, hi) = xb.weight_range();
            let weights: Vec<i32> =
                (0..rows * cols).map(|_| rng.i64_in(lo as i64, hi as i64) as i32).collect();
            xb.program(&weights).unwrap();
            let max_code = (1u64 << g.input_bits) - 1;
            // Binary activations half the time — the aggregation case.
            let binary = rng.bool();
            let input: Vec<u32> = (0..rows)
                .map(|_| rng.u64_in(0, if binary { 1 } else { max_code }) as u32)
                .collect();
            let want = xb.evaluate_reference(&input).unwrap();
            let got = xb.evaluate(&input).unwrap();
            assert_eq!(
                got, want,
                "dispatch mismatch: {rows}x{cols} adc={} cell={} in={} binary={binary} clip_free={}",
                g.adc_bits, g.cell_bits, g.input_bits, xb.clip_free()
            );
            let mut out = vec![0i64; cols];
            xb.evaluate_into(&input, &mut out).unwrap();
            assert_eq!(out, want);
            if binary {
                let mut mask = vec![0u64; rows.div_ceil(64)];
                for (r, &x) in input.iter().enumerate() {
                    if x == 1 {
                        mask[r / 64] |= 1 << (r % 64);
                    }
                }
                xb.accumulate_rows(&mask, &mut out).unwrap();
                assert_eq!(out, want, "packed accumulate mismatch");
            }
        });
    }

    #[test]
    fn clip_free_tracks_the_programmed_weights() {
        // Default 512-row geometry (adc_bits = 13): the extreme programs
        // sit exactly on the converter boundary — still clip-free.
        let mut xb = xbar(512, 4);
        xb.program(&vec![-8; 512 * 4]).unwrap(); // plane min = -4096 = lo
        assert!(xb.clip_free());
        xb.program(&vec![7; 512 * 4]).unwrap(); // plane max = 3584 <= 4095
        assert!(xb.clip_free());
        // A narrow ADC clips the same program.
        let mut g = CrossbarGeometry::new(64, 4);
        g.adc_bits = 4;
        let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
        xb.program(&vec![1; 64 * 4]).unwrap(); // plane max = 64 > 7
        assert!(!xb.clip_free());
        // ... and reprogramming small weights restores the fast path.
        let mut w = vec![0; 64 * 4];
        w[0] = 1;
        xb.program(&w).unwrap();
        assert!(xb.clip_free());
    }

    /// The dense-word / sparse-word dispatch of `accumulate_rows` is
    /// bit-identical to the bit-serial reference at every mask density —
    /// empty and full words, words straddling `DENSE_WORD_THRESHOLD`,
    /// and ragged tail words (rows % 64 ≠ 0) — in both the clipping and
    /// the non-clipping ADC regime.
    #[test]
    fn dense_and_sparse_mask_words_match_the_reference() {
        forall(40, |rng: &mut Rng| {
            let rows = rng.index(220) + 1; // up to 4 words, tails common
            let cols = rng.index(40) + 1;
            let mut g = CrossbarGeometry::new(rows, cols);
            g.cell_bits = rng.u64_in(2, 5) as u32;
            g.adc_bits = rng.u64_in(3, 16) as u32; // narrow ADCs clip
            let mut xb = MvmCrossbar::new(g, DeviceParams::default_45nm()).unwrap();
            let (lo, hi) = xb.weight_range();
            let weights: Vec<i32> =
                (0..rows * cols).map(|_| rng.i64_in(lo as i64, hi as i64) as i32).collect();
            xb.program(&weights).unwrap();
            // Per word, force a density class: empty, full, sparse, or
            // straddling the dense dispatch threshold.
            let mut mask = vec![0u64; rows.div_ceil(64)];
            for (w, word) in mask.iter_mut().enumerate() {
                let slab = (rows - w * 64).min(64) as u64;
                let ones = match rng.index(5) {
                    0 => 0,
                    1 => slab,
                    2 => rng.u64_in(1, 8.min(slab)),
                    3 => rng.u64_in(1, slab),
                    _ => rng.u64_in(28.min(slab), 36.min(slab)),
                };
                let mut bits = 0u64;
                let mut set = 0;
                while set < ones {
                    let b = rng.index(slab as usize) as u64;
                    if bits >> b & 1 == 0 {
                        bits |= 1 << b;
                        set += 1;
                    }
                }
                *word = bits;
            }
            let input: Vec<u32> =
                (0..rows).map(|r| (mask[r / 64] >> (r % 64) & 1) as u32).collect();
            let want = xb.evaluate_reference(&input).unwrap();
            let mut out = vec![0i64; cols];
            xb.accumulate_rows(&mask, &mut out).unwrap();
            assert_eq!(out, want, "{rows}x{cols} adc={} mask={mask:?}", g.adc_bits);
            // Prefix outputs (a programmed sub-tile's column group)
            // agree with the leading reference columns on both paths.
            let k = rng.index(cols) + 1;
            let mut head = vec![0i64; k];
            xb.accumulate_rows(&mask, &mut head).unwrap();
            assert_eq!(head, want[..k], "column-group prefix mismatch");
        });
    }

    #[test]
    fn empty_and_full_masks_hit_both_dispatch_sides() {
        // 100 rows: word 0 full (dense path), word 1 a ragged 36-row
        // tail — full tail popcount 36 ≥ threshold, so dense too.
        let mut xb = xbar(100, 8);
        let weights: Vec<i32> = (0..100 * 8).map(|i| (i % 15) as i32 - 8).collect();
        xb.program(&weights).unwrap();
        let want = xb.evaluate_reference(&vec![1u32; 100]).unwrap();
        let mut out = vec![0i64; 8];
        xb.accumulate_rows(&[!0u64, (1u64 << 36) - 1], &mut out).unwrap();
        assert_eq!(out, want, "full mask");
        // Empty mask: zeros (clamped 0), no rows touched.
        xb.accumulate_rows(&[0, 0], &mut out).unwrap();
        assert_eq!(out, vec![0i64; 8]);
        // One word dense, the other sparse, in the same call.
        let mask = [!0u64, 0b101];
        let input: Vec<u32> = (0..100).map(|r| (mask[r / 64] >> (r % 64) & 1) as u32).collect();
        xb.accumulate_rows(&mask, &mut out).unwrap();
        assert_eq!(out, xb.evaluate_reference(&input).unwrap());
    }

    #[test]
    fn accumulate_rows_validates_mask_and_arity() {
        let mut xb = xbar(70, 8);
        xb.program(&vec![1; 70 * 8]).unwrap();
        let mut out = vec![0i64; 8];
        assert!(xb.accumulate_rows(&[0u64; 1], &mut out).is_err()); // 70 rows need 2 words
        assert!(xb.accumulate_rows(&[0, 1u64 << 6], &mut out).is_err()); // row 70 out of range
        assert!(xb.accumulate_rows(&[0, 0], &mut vec![0i64; 9]).is_err()); // too many outputs
        xb.accumulate_rows(&[0b101, 0], &mut out).unwrap(); // rows 0 and 2
        assert_eq!(out, vec![2i64; 8]);
        // Column-group prefix: out narrower than the array.
        let mut head = vec![0i64; 3];
        xb.accumulate_rows(&[0b101, 0], &mut head).unwrap();
        assert_eq!(head, vec![2, 2, 2]);
    }

    #[test]
    fn evaluate_into_rejects_wrong_output_arity() {
        let xb = xbar(4, 4);
        assert!(xb.evaluate_into(&[0; 4], &mut vec![0i64; 3]).is_err());
        assert!(xb.evaluate_into(&[0; 4], &mut vec![0i64; 5]).is_err());
    }
}
