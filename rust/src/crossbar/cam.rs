//! Resistive CAM crossbar (paper Fig. 2(c)).
//!
//! 2T2R ternary cells perform an XNOR match of the stored key against the
//! search data on every row in parallel (*search*), or an order comparison
//! against calibrated bit-line voltages (*compare*, used by the scan CAM).
//! Functionally equivalent to `python/compile/kernels/cam.py`.
//!
//! DESIGN.md: §3 (architecture level).

use crate::config::{CrossbarGeometry, DeviceParams};
use crate::device::{Driver, MatchLineSense};
use crate::error::{Error, Result};
use crate::units::{Energy, Power, Time};

/// One resistive CAM crossbar holding up to `rows` keys of `cols` bits.
#[derive(Debug, Clone)]
pub struct CamCrossbar {
    geometry: CrossbarGeometry,
    device: DeviceParams,
    /// Stored keys; `None` = row not programmed (never matches).
    keys: Vec<Option<u64>>,
}

impl CamCrossbar {
    pub fn new(geometry: CrossbarGeometry, device: DeviceParams) -> Result<CamCrossbar> {
        geometry.validate()?;
        if geometry.cols > 64 {
            return Err(Error::Hardware(format!(
                "CAM width {} exceeds 64-bit key model",
                geometry.cols
            )));
        }
        Ok(CamCrossbar { keys: vec![None; geometry.rows], geometry, device })
    }

    pub fn geometry(&self) -> &CrossbarGeometry {
        &self.geometry
    }

    /// Largest key storable in `cols` bits.
    pub fn max_key(&self) -> u64 {
        if self.geometry.cols >= 64 {
            u64::MAX
        } else {
            (1u64 << self.geometry.cols) - 1
        }
    }

    /// Program one row with a key.
    pub fn write(&mut self, row: usize, key: u64) -> Result<()> {
        if row >= self.geometry.rows {
            return Err(Error::Hardware(format!(
                "row {row} out of range ({} rows)",
                self.geometry.rows
            )));
        }
        if key > self.max_key() {
            return Err(Error::Hardware(format!(
                "key {key} exceeds {}-bit CAM width",
                self.geometry.cols
            )));
        }
        self.keys[row] = Some(key);
        Ok(())
    }

    /// Program consecutive rows from a slice, starting at row 0.
    pub fn load(&mut self, keys: &[u64]) -> Result<()> {
        if keys.len() > self.geometry.rows {
            return Err(Error::Hardware(format!(
                "{} keys exceed {} CAM rows",
                keys.len(),
                self.geometry.rows
            )));
        }
        self.keys.fill(None);
        for (i, &k) in keys.iter().enumerate() {
            self.write(i, k)?;
        }
        Ok(())
    }

    /// Number of programmed rows.
    pub fn occupancy(&self) -> usize {
        self.keys.iter().filter(|k| k.is_some()).count()
    }

    /// *Search* operation: all match-lines fire in parallel; returns the
    /// rows whose stored key equals `query` (paper Fig. 3(c)).
    pub fn search(&self, query: u64) -> Vec<usize> {
        self.keys
            .iter()
            .enumerate()
            .filter_map(|(i, k)| (*k == Some(query)).then_some(i))
            .collect()
    }

    /// *Compare* operation of the scan CAM: rows whose key satisfies
    /// `key <= value` (calibrated increasing bit-line voltages LSB→MSB
    /// realize the threshold compare; paper §2.2).
    pub fn compare_le(&self, value: u64) -> Vec<usize> {
        self.keys
            .iter()
            .enumerate()
            .filter_map(|(i, k)| match k {
                Some(key) if *key <= value => Some(i),
                _ => None,
            })
            .collect()
    }

    /// Scan-CAM range lookup (paper Fig. 3(d)): rows store the CSR row
    /// pointers RP; the owner of edge position `pos` is the last row with
    /// `RP[row] <= pos`.  Returns `None` when no row qualifies.
    pub fn scan_owner(&self, pos: u64) -> Option<usize> {
        self.compare_le(pos).into_iter().max()
    }

    /// Latency of one CAM operation (search or compare): driver + match
    /// line settle + MLSA sensing.
    pub fn op_latency(&self) -> Time {
        Driver::new(&self.device).latency()
            + self.device.cam_settle
            + MatchLineSense::new(&self.device).latency()
    }

    /// Dynamic energy of one CAM operation.
    pub fn op_energy(&self) -> Energy {
        Driver::new(&self.device).energy() + MatchLineSense::new(&self.device).energy()
    }

    /// Average power while continuously searching.
    pub fn active_power(&self) -> Power {
        self.op_energy() / self.op_latency()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DeviceParams;
    use crate::testing::{forall, Rng};

    fn cam(rows: usize, cols: usize) -> CamCrossbar {
        CamCrossbar::new(CrossbarGeometry::new(rows, cols), DeviceParams::default_45nm()).unwrap()
    }

    #[test]
    fn search_finds_all_matches_and_only_matches() {
        let mut c = cam(16, 32);
        c.load(&[5, 9, 5, 7, 5]).unwrap();
        assert_eq!(c.search(5), vec![0, 2, 4]);
        assert_eq!(c.search(7), vec![3]);
        assert!(c.search(42).is_empty());
    }

    #[test]
    fn unprogrammed_rows_never_match() {
        let mut c = cam(8, 16);
        c.write(3, 0).unwrap();
        // query 0 must match only the programmed row, not the empty ones
        assert_eq!(c.search(0), vec![3]);
    }

    #[test]
    fn compare_le_is_a_threshold() {
        let mut c = cam(8, 16);
        c.load(&[0, 2, 5, 9]).unwrap();
        assert_eq!(c.compare_le(4), vec![0, 1]);
        assert_eq!(c.compare_le(9), vec![0, 1, 2, 3]);
        assert!(c.compare_le(0).len() == 1);
    }

    #[test]
    fn scan_owner_matches_csr_semantics() {
        // RP = [0, 2, 2, 5, 9]: row pointers of a 4-node CSR (node 1 empty).
        let mut c = cam(8, 16);
        c.load(&[0, 2, 2, 5]).unwrap();
        // pos 0,1 -> node 0; pos 2..4 -> node 2 (last row with RP<=pos
        // because node 1 is empty); pos 5..8 -> node 3.
        assert_eq!(c.scan_owner(0), Some(0));
        assert_eq!(c.scan_owner(1), Some(0));
        assert_eq!(c.scan_owner(2), Some(2));
        assert_eq!(c.scan_owner(4), Some(2));
        assert_eq!(c.scan_owner(5), Some(3));
        assert_eq!(c.scan_owner(8), Some(3));
    }

    #[test]
    fn property_scan_owner_agrees_with_linear_search() {
        forall(32, |rng: &mut Rng| {
            let n = rng.index(30) + 1;
            let mut rp = vec![0u64];
            for _ in 0..n {
                let last = *rp.last().unwrap();
                rp.push(last + rng.u64_in(0, 4));
            }
            let total = *rp.last().unwrap();
            if total == 0 {
                return;
            }
            let mut c = cam(64, 32);
            c.load(&rp[..n]).unwrap();
            let pos = rng.u64_in(0, total - 1);
            let got = c.scan_owner(pos).expect("some row must own a valid pos");
            // linear-search oracle: the row i with rp[i] <= pos < rp[i+1],
            // taking the *last* such i (empty rows share pointers).
            let want = (0..n).rev().find(|&i| rp[i] <= pos).unwrap();
            assert_eq!(got, want, "pos={pos} rp={rp:?}");
        });
    }

    #[test]
    fn op_latency_matches_calibration() {
        // driver 0.78 + settle 1.92 + MLSA 1.14 = 3.84 ns per op.
        let c = cam(512, 32);
        crate::testing::assert_close(c.op_latency().as_ns(), 3.84, 1e-9);
    }

    #[test]
    fn power_matches_calibration() {
        // 2 ops (search+scan) per node at 0.8064 pJ / 3.84 ns = 0.21 mW.
        let c = cam(512, 32);
        crate::testing::assert_close(c.active_power().as_mw(), 0.21, 0.001);
    }

    #[test]
    fn rejects_invalid_writes() {
        let mut c = cam(4, 8);
        assert!(c.write(4, 0).is_err()); // row out of range
        assert!(c.write(0, 256).is_err()); // key exceeds 8-bit width
        assert!(c.load(&[0; 5]).is_err()); // too many keys
        assert!(CamCrossbar::new(
            CrossbarGeometry::new(4, 128),
            DeviceParams::default_45nm()
        )
        .is_err()); // width > 64
    }

    #[test]
    fn occupancy_counts_programmed_rows() {
        let mut c = cam(8, 8);
        assert_eq!(c.occupancy(), 0);
        c.load(&[1, 2, 3]).unwrap();
        assert_eq!(c.occupancy(), 3);
        c.load(&[9]).unwrap(); // reload clears
        assert_eq!(c.occupancy(), 1);
    }
}
