//! E6 — the §4.2 case study: city-wide taxi demand/supply forecasting with
//! the hetGNN-LSTM, on IMA-GNN in both edge settings.
//!
//! Generates a synthetic taxi city (road / proximity / destination edges +
//! demand history), runs the AOT-compiled hetGNN-LSTM artifact for a batch
//! of taxis, and reports the Table-1 style modeled latency/power of both
//! deployments for this exact workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example taxi_forecast
//! ```

use ima_gnn::cores::GnnWorkload;
use ima_gnn::graph::NeighborSampler;
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::report::Table;
use ima_gnn::runtime::{ArtifactStore, Tensor};
use ima_gnn::testing::Rng;
use ima_gnn::workload::{TaxiCity, TaxiCityConfig, EDGE_TYPES};

const BATCH: usize = 32;
const SAMPLE: usize = 8;
const TABLE: usize = 256;
const HIST: usize = 12;
const HIDDEN: usize = 64;
const FIN: usize = 128; // 2 channels × 8×8 grid
const HORIZON: usize = 3;

fn main() -> ima_gnn::Result<()> {
    // --- the city --------------------------------------------------------
    let city = TaxiCity::generate(TaxiCityConfig {
        taxis: 2_000, // scaled city; the model extrapolates to 10 000
        ..Default::default()
    })?;
    println!(
        "generated city: {} taxis, edges per type: road {}, proximity {}, destination {}",
        city.num_taxis(),
        city.graphs[0].num_edges(),
        city.graphs[1].num_edges(),
        city.graphs[2].num_edges()
    );

    // --- batch assembly (what each edge device ships) ---------------------
    let mut rng = Rng::new(5);
    let batch_taxis: Vec<usize> = (0..BATCH).map(|i| i * 7 % city.num_taxis()).collect();

    // own-region history [B, P, Fin]
    let mut x_hist = Vec::with_capacity(BATCH * HIST * FIN);
    for &t in &batch_taxis {
        x_hist.extend_from_slice(&city.history[t]);
    }

    // neighbor indices per edge type [B, 3, S] into the shipped table
    let samplers: Vec<NeighborSampler> =
        (0..EDGE_TYPES).map(|r| NeighborSampler::new(SAMPLE, 100 + r as u64)).collect();
    let mut nbr_idx = Vec::with_capacity(BATCH * EDGE_TYPES * SAMPLE);
    for &t in &batch_taxis {
        for (r, sampler) in samplers.iter().enumerate() {
            for s in sampler.sample_row(&city.graphs[r], t) {
                // map global taxi id onto the bounded table (mod mapping for
                // the demo; the coordinator owns the real table assignment)
                nbr_idx.push(if s < 0 { -1 } else { s % TABLE as i32 });
            }
        }
    }

    // neighbor per-frame embedding table [T, P, H] (previous round output)
    let nbr_table: Vec<f32> =
        (0..TABLE * HIST * HIDDEN).map(|_| rng.f64_in(-0.5, 0.5) as f32).collect();

    // model parameters (randomly initialized; training is out of scope —
    // the paper evaluates inference latency/power)
    let glorot = |rng: &mut Rng, fan_in: usize, fan_out: usize, n: usize| -> Vec<f32> {
        let lim = (6.0 / (fan_in + fan_out) as f64).sqrt();
        (0..n).map(|_| rng.f64_in(-lim, lim) as f32).collect()
    };
    let w_embed = glorot(&mut rng, FIN, HIDDEN, FIN * HIDDEN);
    let w_msg = glorot(&mut rng, HIDDEN, HIDDEN, EDGE_TYPES * HIDDEN * HIDDEN);
    let w_i = glorot(&mut rng, HIDDEN, 4 * HIDDEN, HIDDEN * 4 * HIDDEN);
    let w_h = glorot(&mut rng, HIDDEN, 4 * HIDDEN, HIDDEN * 4 * HIDDEN);
    let b = vec![0.0f32; 4 * HIDDEN];
    let w_out = glorot(&mut rng, HIDDEN, HORIZON * FIN, HIDDEN * HORIZON * FIN);

    // --- run the AOT hetGNN-LSTM through PJRT ----------------------------
    let store = ArtifactStore::open(&ima_gnn::runtime::default_artifact_dir())?;
    let inputs = vec![
        Tensor::f32(&[BATCH, HIST, FIN], x_hist)?,
        Tensor::i32(&[BATCH, EDGE_TYPES, SAMPLE], nbr_idx)?,
        Tensor::f32(&[TABLE, HIST, HIDDEN], nbr_table)?,
        Tensor::f32(&[FIN, HIDDEN], w_embed)?,
        Tensor::f32(&[EDGE_TYPES, HIDDEN, HIDDEN], w_msg)?,
        Tensor::f32(&[HIDDEN, 4 * HIDDEN], w_i)?,
        Tensor::f32(&[HIDDEN, 4 * HIDDEN], w_h)?,
        Tensor::f32(&[4 * HIDDEN], b)?,
        Tensor::f32(&[HIDDEN, HORIZON * FIN], w_out)?,
    ];
    let t0 = std::time::Instant::now();
    let out = store.run("hetgnn_taxi", &inputs)?;
    let compile_and_run = t0.elapsed();
    let t0 = std::time::Instant::now();
    let out2 = store.run("hetgnn_taxi", &inputs)?;
    let hot = t0.elapsed();
    assert_eq!(out[0].shape, vec![BATCH, HORIZON, FIN]);
    assert_eq!(out[0], out2[0], "inference must be deterministic");

    let pred = out[0].as_f32()?;
    println!(
        "predicted demand frames: [B={BATCH}, Q={HORIZON}, {FIN}]; taxi 0, t+1, cell sums: {:.2}",
        pred[..FIN].iter().sum::<f32>()
    );
    println!(
        "PJRT wall: {:.1} ms cold (compile) / {:.2} ms hot",
        compile_and_run.as_secs_f64() * 1e3,
        hot.as_secs_f64() * 1e3
    );

    // --- Table 1 for this workload ---------------------------------------
    let model = NetModel::paper(&GnnWorkload::taxi())?;
    let topo = Topology::taxi();
    let mut t = Table::new(
        "modeled edge figures (taxi workload, N=10000, cs=10)",
        &["Setting", "Compute", "Communicate", "Total", "Compute power"],
    );
    for s in [Setting::Centralized, Setting::Decentralized] {
        let l = model.latency(s, topo);
        t.row(&[
            format!("{s:?}"),
            l.compute.to_string(),
            l.communicate.to_string(),
            l.total().to_string(),
            model.compute_power(s).to_string(),
        ]);
    }
    t.print();
    println!(
        "paper's conclusion: decentralized wins compute ~10x here, loses communication \
         ~123x -> semi-decentralized (see examples/semi_decentralized.rs)"
    );
    Ok(())
}
