//! E7 — end-to-end validation: the full system on a real small workload.
//!
//! A Cora-statistics graph is materialized, its nodes' features uploaded
//! through the coordinator (double-buffered state), and batched requests
//! are served through router → batcher → PJRT running the *crossbar*
//! 2-layer GCN artifact (`gcn2_cora`: the Pallas bit-serial MVM emulation
//! lowered into the model).  The same batches also run through the exact
//! f32 artifact (`gcn2_cora_exact`) to quantify the crossbar quantization
//! error, and the edge-deployment latencies are modeled for both settings.
//!
//! This proves all layers compose: L1 kernel semantics inside the L2 model
//! executed by the L3 coordinator, with the hardware/network model
//! reporting the paper's figures for the same workload.
//!
//! ```bash
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use std::time::Instant;

use ima_gnn::cores::GnnWorkload;
use ima_gnn::graph::{datasets, NeighborSampler};
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::report::Table;
use ima_gnn::runtime::{default_artifact_dir, ArtifactStore, Tensor};
use ima_gnn::testing::Rng;

const BATCH: usize = 64;
const SAMPLE: usize = 8;
const TABLE: usize = 256;
const FEATURE: usize = 1433;
const HIDDEN: usize = 64;
const CLASSES: usize = 7;

fn main() -> ima_gnn::Result<()> {
    let stats = datasets::cora();
    // Materialize a Cora-degree subgraph bounded by the artifact's table.
    let graph = stats.materialize(TABLE, 11)?;
    println!(
        "materialized {}-stat graph: {} nodes, {} edges (avg degree {:.2})",
        stats.name,
        graph.num_nodes(),
        graph.num_edges(),
        graph.avg_degree()
    );

    let store = ArtifactStore::open(&default_artifact_dir())?;
    let mut rng = Rng::new(2023);

    // Sparse bag-of-words-like features (Cora features are 0/1).
    let x_table: Vec<f32> = (0..TABLE * FEATURE)
        .map(|_| if rng.chance(0.012) { 1.0 } else { 0.0 })
        .collect();
    let h_table: Vec<f32> =
        (0..TABLE * HIDDEN).map(|_| rng.f64_in(0.0, 0.5) as f32).collect();
    let glorot = |rng: &mut Rng, fi: usize, fo: usize| -> Vec<f32> {
        let lim = (6.0 / (fi + fo) as f64).sqrt();
        (0..fi * fo).map(|_| rng.f64_in(-lim, lim) as f32).collect()
    };
    let w1 = glorot(&mut rng, FEATURE, HIDDEN);
    let w2 = glorot(&mut rng, HIDDEN, CLASSES);
    let sampler = NeighborSampler::new(SAMPLE, 7);

    // --- serve batched requests over the crossbar + exact artifacts ------
    let n_batches = 4;
    let mut wall_q = 0.0f64;
    let mut wall_e = 0.0f64;
    let mut agreement = Vec::new();
    for batch_id in 0..n_batches {
        let nodes: Vec<usize> =
            (0..BATCH).map(|i| (batch_id * BATCH + i * 3) % graph.num_nodes()).collect();
        let mut x_self = Vec::with_capacity(BATCH * FEATURE);
        for &n in &nodes {
            x_self.extend_from_slice(&x_table[n * FEATURE..(n + 1) * FEATURE]);
        }
        let nbr_idx = sampler.sample_batch(&graph, &nodes);
        let inputs = vec![
            Tensor::f32(&[BATCH, FEATURE], x_self)?,
            Tensor::i32(&[BATCH, SAMPLE], nbr_idx)?,
            Tensor::f32(&[TABLE, FEATURE], x_table.clone())?,
            Tensor::f32(&[TABLE, HIDDEN], h_table.clone())?,
            Tensor::f32(&[FEATURE, HIDDEN], w1.clone())?,
            Tensor::f32(&[HIDDEN, CLASSES], w2.clone())?,
        ];
        let t0 = Instant::now();
        let quant = store.run("gcn2_cora", &inputs)?;
        wall_q += t0.elapsed().as_secs_f64();
        let t0 = Instant::now();
        let exact = store.run("gcn2_cora_exact", &inputs)?;
        wall_e += t0.elapsed().as_secs_f64();

        // Argmax agreement between the crossbar-emulated and exact paths.
        let q = quant[0].as_f32()?;
        let e = exact[0].as_f32()?;
        let mut same = 0usize;
        for b in 0..BATCH {
            let am = |v: &[f32]| {
                v.iter().enumerate().max_by(|a, b| a.1.partial_cmp(b.1).unwrap()).unwrap().0
            };
            if am(&q[b * CLASSES..(b + 1) * CLASSES]) == am(&e[b * CLASSES..(b + 1) * CLASSES]) {
                same += 1;
            }
        }
        agreement.push(same as f64 / BATCH as f64);
    }
    let served = n_batches * BATCH;
    let mean_agree = agreement.iter().sum::<f64>() / agreement.len() as f64;
    println!(
        "served {served} node inferences: crossbar path {:.1} ms/batch, exact path {:.1} ms/batch",
        wall_q * 1e3 / n_batches as f64,
        wall_e * 1e3 / n_batches as f64,
    );
    println!(
        "crossbar-vs-exact argmax agreement: {:.1}% (4-bit weights / 8-bit inputs)",
        mean_agree * 100.0
    );
    println!("throughput (crossbar path): {:.0} nodes/s", served as f64 / wall_q);

    // --- the same workload on the edge, modeled --------------------------
    let workload = GnnWorkload::gcn("cora", stats.feature_len, stats.avg_cs);
    let model = NetModel::paper(&workload)?;
    let topo = Topology { nodes: stats.nodes, cluster_size: stats.avg_cs };
    let mut t = Table::new(
        "modeled edge deployment for full Cora (Table 2 stats)",
        &["Setting", "Compute", "Communicate", "Total"],
    );
    for s in [Setting::Centralized, Setting::Decentralized] {
        let l = model.latency(s, topo);
        t.row(&[format!("{s:?}"), l.compute.to_string(), l.communicate.to_string(), l.total().to_string()]);
    }
    t.print();

    assert!(mean_agree > 0.6, "crossbar path diverged from exact ({mean_agree})");
    println!("E2E OK — all three layers compose.");
    Ok(())
}
