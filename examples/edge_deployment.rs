//! E3 — choosing a deployment for your dataset: Fig. 8 + DES cross-check.
//!
//! Walks the four Table 2 datasets, prints the Fig. 8 computation /
//! communication breakdown for both settings, then validates the analytic
//! numbers with the discrete-event simulator (including a jittered run and
//! a CSMA shared-medium run the closed-form model cannot express).
//!
//! ```bash
//! cargo run --release --example edge_deployment
//! ```

use ima_gnn::cores::GnnWorkload;
use ima_gnn::experiments::Fig8;
use ima_gnn::graph::datasets;
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::report::Table;
use ima_gnn::sim::{simulate, SimConfig};

fn main() -> ima_gnn::Result<()> {
    // --- the analytic figure --------------------------------------------
    let fig8 = Fig8::new()?;
    fig8.render().print();
    println!("\n{}\n", fig8.summary());

    // --- DES cross-validation on a scaled topology ------------------------
    let model = NetModel::paper(&GnnWorkload::taxi())?;
    let mut t = Table::new(
        "DES vs analytic (scaled to 2000 devices per dataset)",
        &["Dataset", "Setting", "Analytic", "DES", "DES +20% jitter", "DES CSMA"],
    );
    for d in datasets::all() {
        let m = NetModel::fig8(&d)?;
        let topo = Topology { nodes: d.nodes.min(2000), cluster_size: d.avg_cs.min(64) };
        for setting in [Setting::Centralized, Setting::Decentralized] {
            let analytic = m.latency(setting, topo).total();
            let des = simulate(&m, setting, topo, &SimConfig::default())?.completion;
            let jit = simulate(
                &m,
                setting,
                topo,
                &SimConfig { link_jitter: 0.2, ..Default::default() },
            )?
            .completion;
            let csma = if setting == Setting::Decentralized {
                simulate(
                    &m,
                    setting,
                    topo,
                    &SimConfig { shared_medium: true, ..Default::default() },
                )?
                .completion
                .to_string()
            } else {
                "-".into()
            };
            t.row(&[
                d.name.to_string(),
                format!("{setting:?}"),
                analytic.to_string(),
                des.to_string(),
                jit.to_string(),
                csma,
            ]);
        }
    }
    t.print();

    // --- decision guide ----------------------------------------------------
    println!("\ndeployment guide (lowest total latency per dataset):");
    for d in datasets::all() {
        let m = NetModel::fig8(&d)?;
        let topo = Topology { nodes: d.nodes, cluster_size: d.avg_cs };
        let cent = m.latency(Setting::Centralized, topo).total();
        let dec = m.latency(Setting::Decentralized, topo).total();
        let winner = if cent < dec { "centralized" } else { "decentralized" };
        println!(
            "  {:<12} -> {winner} (centralized {}, decentralized {})",
            d.name, cent, dec
        );
    }
    let _ = model;
    Ok(())
}
