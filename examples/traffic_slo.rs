//! Traffic walkthrough: when does the hybrid overtake the leader?
//!
//! Fig. 8 and Table 1 price a single unloaded round, but the paper's
//! taxi fleet is a sustained stream: requests queue at the leader's NIC,
//! batches coalesce, and the winning deployment flips with load.  This
//! example drives the E13 traffic engine over the taxi case study at a
//! ladder of offered rates and prints the p95 response per deployment
//! shape, the leader's utilization, and a diurnal-curve run showing the
//! peak-hour tail.
//!
//! `cargo run --release --example traffic_slo`

use ima_gnn::autotune::SettingKind;
use ima_gnn::coordinator::LatencyProvider;
use ima_gnn::cores::GnnWorkload;
use ima_gnn::netmodel::{NetModel, Topology};
use ima_gnn::report::Table;
use ima_gnn::traffic::{deployment_shape, open_loop, ArrivalProcess, BatchPolicy};
use ima_gnn::units::Time;
use ima_gnn::workload::DiurnalCurve;

fn main() -> ima_gnn::Result<()> {
    let model = NetModel::paper(&GnnWorkload::taxi())?;
    let topo = Topology::taxi();
    let policy = BatchPolicy::Deadline { max: 64, max_wait: Time::ms(2.0) };
    let requests = 2_000usize;

    let mut shapes = Vec::with_capacity(3);
    for kind in [SettingKind::Centralized, SettingKind::Semi, SettingKind::Decentralized] {
        let (queues, service) =
            deployment_shape(kind, LatencyProvider::Analytic, &model, topo)?;
        shapes.push((kind.name(), queues, service));
    }

    // --- 1. the rate ladder --------------------------------------------------
    let sat = shapes[0].2.saturation_rate(64);
    let mut t = Table::new(
        format!(
            "taxi study, N={}, cs={}: p95 response vs offered rate \
             (leader saturates at ~{:.0} req/s)",
            topo.nodes, topo.cluster_size, sat
        ),
        &["Offered req/s", "x sat", "Cent p95", "Semi p95", "Dec p95", "Cent util"],
    );
    for rel in [0.1, 0.5, 0.9, 1.5] {
        let rate = rel * sat;
        let mut cells = vec![format!("{rate:.0}"), format!("{rel:.1}")];
        let mut cent_util = String::new();
        for (i, (_, queues, service)) in shapes.iter().enumerate() {
            let queue_rate = queues.per_queue_rate(rate);
            let horizon = Time::s(requests as f64 / queue_rate);
            let arrivals = ArrivalProcess::Poisson { rate: queue_rate }
                .generate(horizon, topo.nodes, 42 + i as u64)?;
            let r = open_loop(1, service, policy, &arrivals)?;
            cells.push(r.latency.p95().to_string());
            if i == 0 {
                cent_util = format!("{:.0}%", r.utilization * 100.0);
            }
        }
        cells.push(cent_util);
        t.row(&cells);
    }
    t.print();
    println!(
        "below saturation the leader's single fast V2X gather wins; past it the\n\
         cluster-head overlay holds its floor while the leader queue diverges.\n"
    );

    // --- 2. a day of taxi demand --------------------------------------------
    let day = Time::s(2.0);
    let curve = DiurnalCurve::new(0.6 * sat, 0.9, day)?;
    let arrivals =
        ArrivalProcess::Diurnal(curve).generate(day, topo.nodes, 7)?;
    let r = open_loop(1, &shapes[0].2, policy, &arrivals)?;
    println!(
        "diurnal day at mean {:.0} req/s (peak {:.0}): {} requests, p50 {}, p95 {}, \
         p99 {} — the peak hour, not the mean, sets the SLO.",
        curve.base_rate,
        curve.peak_rate(),
        r.offered,
        r.latency.p50(),
        r.latency.p95(),
        r.latency.p99(),
    );
    Ok(())
}
