//! E8 — the paper's conclusion: a semi-decentralized deployment balances
//! the communication–computation trade-off.
//!
//! Part 1 *runs* a semi-decentralized round (cluster heads batching their
//! members through the PJRT artifact) and a fully-decentralized round
//! (worker threads exchanging features) on the same graph, checking both
//! produce consistent embeddings.
//! Part 2 sweeps cluster size and graph scale with the E8 latency model,
//! showing where the hybrid beats both extremes.
//!
//! ```bash
//! make artifacts && cargo run --release --example semi_decentralized
//! ```

use ima_gnn::coordinator::{run_decentralized, InferenceService, SemiCoordinator};
use ima_gnn::coordinator::GcnLayerBinding;
use ima_gnn::cores::{FeatureMatrix, GnnWorkload};
use ima_gnn::graph::{fixed_size, generate};
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::report::Table;
use ima_gnn::runtime::{default_artifact_dir, Manifest};
use ima_gnn::testing::Rng;

fn main() -> ima_gnn::Result<()> {
    let dir = default_artifact_dir();
    let svc = InferenceService::start(dir.clone())?;
    let manifest = Manifest::load(&dir)?;
    let binding = GcnLayerBinding::from_spec(manifest.get("gcn_layer_small")?)?;
    let (feature, hidden) = (binding.feature, binding.hidden);

    // --- part 1: run both deployments on one 48-node graph ----------------
    let n = 48;
    let cs = 8;
    let graph = generate::regular(n, 6, 3)?;
    let clustering = fixed_size(n, cs)?;
    let mut rng = Rng::new(9);
    let features = FeatureMatrix::from_fn(n, feature, |_, _| rng.f64_in(0.0, 1.0) as f32);
    let weights_f: Vec<f32> =
        (0..feature * hidden).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect();

    let mut semi = SemiCoordinator::new(
        binding,
        graph,
        clustering.clone(),
        weights_f,
        &GnnWorkload::gcn("semi", feature, cs),
    )?;
    let t0 = std::time::Instant::now();
    let semi_results = semi.round(&svc, &features)?;
    println!(
        "semi-decentralized: {} heads served {} members in {:.1} ms wall (modeled: {})",
        semi.num_heads(),
        semi_results.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        semi_results[0].modeled,
    );

    let weights_q: Vec<i32> = (0..feature * 8).map(|_| rng.i64_in(-8, 7) as i32).collect();
    let model = NetModel::paper(&GnnWorkload::gcn("dec", feature, cs))?;
    let t0 = std::time::Instant::now();
    let dec_results = run_decentralized(&features, &clustering, weights_q, 8, &model)?;
    println!(
        "fully decentralized: {} device threads finished in {:.1} ms wall (modeled: {})",
        dec_results.len(),
        t0.elapsed().as_secs_f64() * 1e3,
        dec_results[0].modeled,
    );
    assert_eq!(semi_results.len(), dec_results.len());

    // --- part 2: where does each deployment win? --------------------------
    let model = NetModel::paper(&GnnWorkload::taxi())?;
    let mut t = Table::new(
        "total latency by deployment (taxi workload)",
        &["N devices", "cs", "Centralized", "Decentralized", "Semi-decentralized"],
    );
    for &(n, cs) in
        &[(1_000usize, 10usize), (10_000, 10), (100_000, 10), (1_000_000, 10), (10_000, 50)]
    {
        let topo = Topology { nodes: n, cluster_size: cs };
        let cent = model.latency(Setting::Centralized, topo).total();
        let dec = model.latency(Setting::Decentralized, topo).total();
        let semi = model.semi_latency(topo, cs as f64).total();
        let mark = |t: ima_gnn::Time| {
            if t <= cent.min(dec).min(semi) {
                format!("{t} *")
            } else {
                t.to_string()
            }
        };
        t.row(&[n.to_string(), cs.to_string(), mark(cent), mark(dec), mark(semi)]);
    }
    t.print();
    println!("* = winner. The hybrid inherits centralized-grade links with per-region compute,");
    println!("  confirming the paper's closing argument for semi-decentralized GNNs [26].");
    Ok(())
}
