//! Quickstart: the three-layer stack in one page.
//!
//! 1. model the accelerator analytically (Table 1 figures),
//! 2. load the AOT-compiled Pallas/JAX artifact through PJRT,
//! 3. run one GCN layer on it — no Python anywhere on this path.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use ima_gnn::cores::GnnWorkload;
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::runtime::{ArtifactStore, Tensor};
use ima_gnn::testing::Rng;

fn main() -> ima_gnn::Result<()> {
    // --- Layer-3 analytics: the paper's network model -------------------
    let model = NetModel::paper(&GnnWorkload::taxi())?;
    let topo = Topology::taxi();
    for setting in [Setting::Centralized, Setting::Decentralized] {
        let l = model.latency(setting, topo);
        println!(
            "{setting:?}: compute {} + communicate {} = {}",
            l.compute,
            l.communicate,
            l.total()
        );
    }

    // --- Runtime: execute the AOT artifact ------------------------------
    let store = ArtifactStore::open(&ima_gnn::runtime::default_artifact_dir())?;
    println!("\nPJRT platform: {}", store.platform());
    let mut rng = Rng::new(1);

    // gcn_layer_small: batch 16, sample 4, feature 64, hidden 32, table 64.
    let x_self = Tensor::f32(&[16, 64], (0..16 * 64).map(|_| rng.f64() as f32).collect())?;
    let nbr_idx = Tensor::i32(
        &[16, 4],
        (0..64).map(|_| if rng.chance(0.25) { -1 } else { rng.index(64) as i32 }).collect(),
    )?;
    let x_table = Tensor::f32(&[64, 64], (0..64 * 64).map(|_| rng.f64() as f32).collect())?;
    let w = Tensor::f32(
        &[64, 32],
        (0..64 * 32).map(|_| rng.f64_in(-0.2, 0.2) as f32).collect(),
    )?;

    let t0 = std::time::Instant::now();
    let out = store.run("gcn_layer_small", &[x_self, nbr_idx, x_table, w])?;
    println!(
        "gcn_layer_small -> {:?} in {:.2} ms (first call compiles)",
        out[0].shape,
        t0.elapsed().as_secs_f64() * 1e3
    );
    let t0 = std::time::Instant::now();
    let emb = out[0].as_f32()?;
    println!(
        "embedding[0][..6] = {:?}",
        &emb[..6].iter().map(|v| (v * 100.0).round() / 100.0).collect::<Vec<_>>()
    );
    let _ = t0;
    Ok(())
}
