//! Packet-fabric congestion walkthrough.
//!
//! Eq. (5) of the paper takes the centralized uplinks as perfectly
//! concurrent — every taxi's 864-byte message lands in t(L_n) ≈ 3.3 ms no
//! matter how many taxis transmit.  This example replays the same gather
//! through the packet-level `netsim` fabric while shrinking the leader's
//! receive-port pool, then shows the decentralized CSMA counterpart and
//! where the semi-decentralized overlay ends up between the two.
//!
//! `cargo run --release --example netsim_fabric`

use ima_gnn::cores::GnnWorkload;
use ima_gnn::netmodel::{NetModel, Setting, Topology};
use ima_gnn::netsim::{simulate_fabric, NetSimConfig, Scenario};
use ima_gnn::report::Table;

fn main() -> ima_gnn::Result<()> {
    let model = NetModel::paper(&GnnWorkload::taxi())?;
    let topo = Topology { nodes: 1000, cluster_size: 10 };

    // --- 1. the leader's NIC is not infinite --------------------------------
    let analytic = model.latency(Setting::Centralized, topo);
    let mut t = Table::new(
        format!(
            "centralized gather, N={} (analytic Eq. 5 comm: {})",
            topo.nodes, analytic.communicate
        ),
        &["Receive ports", "Comm done", "vs Eq. 5", "Queued packets"],
    );
    for ports in [None, Some(256), Some(64), Some(16), Some(4), Some(1)] {
        let cfg = NetSimConfig { rx_ports: ports, ..Default::default() };
        let r = simulate_fabric(&model, Scenario::CentralizedStar, topo, &cfg)?;
        t.row(&[
            ports.map(|p| p.to_string()).unwrap_or_else(|| "unlimited".into()),
            r.comm_done.to_string(),
            format!("{:.1}x", r.comm_done / analytic.communicate),
            r.contended_packets.to_string(),
        ]);
    }
    t.print();
    println!(
        "with unlimited ports the fabric reproduces Eq. 5 exactly; every halving of\n\
         the port pool pushes the gather further from the closed form.\n"
    );

    // --- 2. the decentralized mesh under a shared medium ---------------------
    let dec_analytic = model.latency(Setting::Decentralized, topo);
    let mut t = Table::new(
        format!("decentralized exchange (analytic Eq. 4 comm: {})", dec_analytic.communicate),
        &["Cluster medium", "Comm done", "vs Eq. 4"],
    );
    for channels in [None, Some(4), Some(2), Some(1)] {
        let cfg = NetSimConfig { cluster_channels: channels, ..Default::default() };
        let r = simulate_fabric(&model, Scenario::DecentralizedMesh, topo, &cfg)?;
        t.row(&[
            channels
                .map(|c| format!("{c} channels"))
                .unwrap_or_else(|| "dedicated".into()),
            r.comm_done.to_string(),
            format!("{:.1}x", r.comm_done / dec_analytic.communicate),
        ]);
    }
    t.print();
    println!();

    // --- 3. the hybrid under the same contention ----------------------------
    let mut t = Table::new(
        "round completion under contention (16 rx ports, CSMA clusters)",
        &["Fabric", "Completion"],
    );
    let cfg = NetSimConfig {
        rx_ports: Some(16),
        cluster_channels: Some(1),
        ..Default::default()
    };
    for (name, sc) in [
        ("centralized star", Scenario::CentralizedStar),
        ("decentralized mesh", Scenario::DecentralizedMesh),
        ("semi overlay (heads 10x)", Scenario::SemiOverlay { head_capacity: 10.0 }),
    ] {
        let r = simulate_fabric(&model, sc, topo, &cfg)?;
        t.row(&[name.into(), r.completion.to_string()]);
    }
    t.print();
    println!(
        "under contention the cluster-head overlay gathers in parallel per head —\n\
         the crossover the paper's conclusion predicts (run `ima-gnn netsim --sweep\n\
         --rx-ports 64` for the full E9 grid)."
    );
    Ok(())
}
