"""Crossbar MVM kernel vs pure-jnp oracle: the core L1 correctness signal."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    crossbar_linear,
    crossbar_mvm,
    dequantize,
    quantize_inputs,
    quantize_weights,
)
from compile.kernels import ref

RNG = np.random.default_rng(1234)


def _rand_operands(m, k, n, input_bits=8, weight_bits=4):
    xq = jnp.asarray(RNG.integers(0, 1 << input_bits, (m, k)), jnp.int32)
    lo, hi = -(1 << (weight_bits - 1)), (1 << (weight_bits - 1)) - 1
    gq = jnp.asarray(RNG.integers(lo, hi + 1, (k, n)), jnp.int32)
    return xq, gq


class TestCrossbarMvmExact:
    """Integer path must match the oracle bit-exactly."""

    @pytest.mark.parametrize(
        "m,k,n,xbar_rows",
        [
            (1, 1, 1, 512),  # degenerate
            (4, 512, 32, 512),  # exactly one traversal-sized crossbar
            (8, 512, 512, 512),  # one aggregation-sized crossbar
            (8, 128, 128, 512),  # feature-extraction tile, k < xbar_rows
            (17, 300, 33, 128),  # ragged: padding in every dimension
            (3, 1537, 5, 512),  # k spans 4 crossbars with remainder
        ],
    )
    def test_matches_ref(self, m, k, n, xbar_rows):
        xq, gq = _rand_operands(m, k, n)
        got = crossbar_mvm(xq, gq, xbar_rows=xbar_rows, block_m=16, block_n=16)
        want = ref.crossbar_mvm_ref(xq, gq, xbar_rows=xbar_rows)
        assert got.shape == (m, n)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_plain_matmul_when_adc_lossless(self):
        # With a lossless ADC the bit-serial path is exactly x @ g.
        xq, gq = _rand_operands(9, 200, 13)
        got = crossbar_mvm(xq, gq, xbar_rows=512)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(xq @ gq))

    @pytest.mark.parametrize("adc_bits", [4, 6, 8])
    def test_adc_clipping_matches_ref(self, adc_bits):
        xq, gq = _rand_operands(6, 600, 24)
        got = crossbar_mvm(xq, gq, adc_bits=adc_bits, xbar_rows=256, block_m=8, block_n=8)
        want = ref.crossbar_mvm_ref(xq, gq, adc_bits=adc_bits, xbar_rows=256)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
        # A tight ADC must actually clip somewhere on this workload,
        # otherwise the test exercises nothing.
        lossless = ref.crossbar_mvm_ref(xq, gq, adc_bits=24, xbar_rows=256)
        if adc_bits == 4:
            assert not np.array_equal(np.asarray(want), np.asarray(lossless))

    @pytest.mark.parametrize("input_bits", [1, 2, 4, 8])
    def test_input_bit_widths(self, input_bits):
        xq = jnp.asarray(RNG.integers(0, 1 << input_bits, (5, 96)), jnp.int32)
        gq = jnp.asarray(RNG.integers(-8, 8, (96, 7)), jnp.int32)
        got = crossbar_mvm(xq, gq, input_bits=input_bits, xbar_rows=64, block_m=8, block_n=8)
        want = ref.crossbar_mvm_ref(xq, gq, input_bits=input_bits, xbar_rows=64)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_rejects_bad_shapes(self):
        with pytest.raises(ValueError):
            crossbar_mvm(jnp.zeros((2, 3), jnp.int32), jnp.zeros((4, 5), jnp.int32))
        with pytest.raises(ValueError):
            crossbar_mvm(jnp.zeros((2,), jnp.int32), jnp.zeros((2, 2), jnp.int32))


@settings(max_examples=12, deadline=None)
@given(
    m=st.integers(1, 24),
    k=st.integers(1, 320),
    n=st.integers(1, 24),
    xbar_rows=st.sampled_from([32, 64, 128, 256]),
    adc_bits=st.sampled_from([6, 10, 13]),
    input_bits=st.sampled_from([2, 4, 8]),
)
def test_hypothesis_shape_sweep(m, k, n, xbar_rows, adc_bits, input_bits):
    """Kernel == oracle over a randomized shape/param grid."""
    rng = np.random.default_rng(m * 1000003 + k * 1009 + n)
    xq = jnp.asarray(rng.integers(0, 1 << input_bits, (m, k)), jnp.int32)
    gq = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int32)
    got = crossbar_mvm(
        xq, gq, input_bits=input_bits, adc_bits=adc_bits, xbar_rows=xbar_rows,
        block_m=8, block_n=8,
    )
    want = ref.crossbar_mvm_ref(
        xq, gq, input_bits=input_bits, adc_bits=adc_bits, xbar_rows=xbar_rows
    )
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestQuantization:
    def test_weight_quantization_roundtrip(self):
        w = jnp.asarray(RNG.normal(size=(64, 32)), jnp.float32)
        gq, scale = quantize_weights(w, 4)
        assert int(jnp.max(gq)) <= 7 and int(jnp.min(gq)) >= -8
        err = jnp.max(jnp.abs(gq * scale - w))
        assert float(err) <= float(scale) / 2 + 1e-6

    def test_input_quantization_range(self):
        x = jnp.asarray(RNG.normal(size=(16, 8)) * 10, jnp.float32)
        xq, scale, zero = quantize_inputs(x, 8)
        assert int(jnp.min(xq)) >= 0 and int(jnp.max(xq)) <= 255
        recon = xq * scale + zero
        assert float(jnp.max(jnp.abs(recon - x))) <= float(scale) / 2 + 1e-5

    def test_more_weight_bits_reduce_error(self):
        w = jnp.asarray(RNG.normal(size=(128, 16)), jnp.float32)
        errs = []
        for bits in (2, 4, 6):
            gq, s = quantize_weights(w, bits)
            errs.append(float(jnp.max(jnp.abs(gq * s - w))))
        assert errs[0] > errs[1] > errs[2]

    def test_rejects_bad_bits(self):
        with pytest.raises(ValueError):
            quantize_weights(jnp.ones((2, 2)), 1)
        with pytest.raises(ValueError):
            quantize_inputs(jnp.ones((2, 2)), 0)


class TestCrossbarLinear:
    def test_error_bounded_by_quantization(self):
        x = jnp.asarray(RNG.normal(size=(8, 200)), jnp.float32)
        w = jnp.asarray(RNG.normal(size=(200, 16)), jnp.float32)
        y = crossbar_linear(x, w, xbar_rows=128)
        exact = x @ w
        # 4-bit weights / 8-bit inputs: relative error stays moderate.
        rel = float(jnp.max(jnp.abs(y - exact)) / jnp.max(jnp.abs(exact)))
        assert rel < 0.35
        # And matches its own oracle tightly.
        y_ref = ref.crossbar_linear_ref(x, w, xbar_rows=128)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), rtol=1e-5, atol=1e-5)

    def test_higher_precision_tracks_exact(self):
        x = jnp.asarray(RNG.normal(size=(4, 64)), jnp.float32)
        w = jnp.asarray(RNG.normal(size=(64, 8)), jnp.float32)
        coarse = crossbar_linear(x, w, weight_bits=2)
        fine = crossbar_linear(x, w, weight_bits=6)
        exact = x @ w
        assert float(jnp.mean(jnp.abs(fine - exact))) < float(jnp.mean(jnp.abs(coarse - exact)))
