"""Aggregation kernels (gather_sum / gather_mean) vs oracles."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gather_mean, gather_sum
from compile.kernels import ref

RNG = np.random.default_rng(42)


class TestGatherSum:
    @pytest.mark.parametrize("n,f,m,s,block", [(1, 1, 1, 1, 128), (50, 19, 23, 5, 8), (64, 128, 130, 8, 64)])
    def test_matches_ref(self, n, f, m, s, block):
        x = jnp.asarray(RNG.normal(size=(n, f)), jnp.float32)
        idx = jnp.asarray(RNG.integers(-1, n, (m, s)), jnp.int32)
        got = gather_sum(x, idx, block_m=block)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.gather_sum_ref(x, idx)), rtol=1e-6, atol=1e-6
        )

    def test_padding_neighbors_contribute_zero(self):
        x = jnp.ones((4, 3), jnp.float32)
        idx = jnp.asarray([[0, -1, -1], [-1, -1, -1]], jnp.int32)
        got = np.asarray(gather_sum(x, idx))
        np.testing.assert_allclose(got[0], 1.0)
        np.testing.assert_allclose(got[1], 0.0)

    def test_duplicate_neighbors_count_twice(self):
        x = jnp.asarray([[1.0, 2.0]], jnp.float32)
        idx = jnp.asarray([[0, 0]], jnp.int32)
        np.testing.assert_allclose(np.asarray(gather_sum(x, idx))[0], [2.0, 4.0])

    def test_integer_features(self):
        x = jnp.asarray(RNG.integers(0, 100, (10, 4)), jnp.int32)
        idx = jnp.asarray(RNG.integers(0, 10, (6, 3)), jnp.int32)
        got = gather_sum(x, idx)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.gather_sum_ref(x, idx)))

    def test_rejects_bad_rank(self):
        with pytest.raises(ValueError):
            gather_sum(jnp.zeros((3,)), jnp.zeros((2, 2), jnp.int32))


class TestGatherMean:
    def test_matches_ref(self):
        x = jnp.asarray(RNG.normal(size=(30, 7)), jnp.float32)
        idx = jnp.asarray(RNG.integers(-1, 30, (11, 4)), jnp.int32)
        np.testing.assert_allclose(
            np.asarray(gather_mean(x, idx, block_m=4)),
            np.asarray(ref.gather_mean_ref(x, idx)),
            rtol=1e-6,
            atol=1e-6,
        )

    def test_mean_counts_only_valid(self):
        x = jnp.asarray([[2.0], [4.0]], jnp.float32)
        idx = jnp.asarray([[0, 1, -1, -1]], jnp.int32)
        np.testing.assert_allclose(np.asarray(gather_mean(x, idx))[0], [3.0])

    def test_all_padding_yields_zero(self):
        x = jnp.ones((3, 2), jnp.float32)
        idx = jnp.full((2, 3), -1, jnp.int32)
        np.testing.assert_allclose(np.asarray(gather_mean(x, idx)), 0.0)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(1, 80),
    f=st.integers(1, 40),
    m=st.integers(1, 50),
    s=st.integers(1, 10),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_gather_sweep(n, f, m, s, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(n, f)), jnp.float32)
    idx = jnp.asarray(rng.integers(-1, n, (m, s)), jnp.int32)
    np.testing.assert_allclose(
        np.asarray(gather_sum(x, idx, block_m=16)),
        np.asarray(ref.gather_sum_ref(x, idx)),
        rtol=1e-5,
        atol=1e-5,
    )
    np.testing.assert_allclose(
        np.asarray(gather_mean(x, idx, block_m=16)),
        np.asarray(ref.gather_mean_ref(x, idx)),
        rtol=1e-5,
        atol=1e-5,
    )
