"""Traversal-core CAM kernels (search + scan) vs oracles and CSR invariants."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import cam_scan, cam_search
from compile.kernels import ref

RNG = np.random.default_rng(7)


class TestCamSearch:
    @pytest.mark.parametrize("n,block", [(1, 512), (100, 32), (513, 512), (2048, 256)])
    def test_matches_ref(self, n, block):
        keys = jnp.asarray(RNG.integers(0, 64, (n,)), jnp.int32)
        q = int(RNG.integers(0, 64))
        got = cam_search(keys, q, block=block)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.cam_search_ref(keys, q)))

    def test_no_match(self):
        keys = jnp.arange(10, dtype=jnp.int32)
        assert int(jnp.sum(cam_search(keys, 999))) == 0

    def test_all_match(self):
        keys = jnp.full((77,), 5, jnp.int32)
        assert int(jnp.sum(cam_search(keys, 5, block=16))) == 77

    def test_padding_rows_never_fire(self):
        # n=5 with block=4 pads 3 rows; a query of -1 must not match padding.
        keys = jnp.asarray([0, 1, 2, 3, 4], jnp.int32)
        got = cam_search(keys, -1, block=4)
        assert got.shape == (5,)
        assert int(jnp.sum(got)) == 0

    def test_rejects_bad_rank(self):
        with pytest.raises(ValueError):
            cam_search(jnp.zeros((2, 2), jnp.int32), 0)


def _random_rp(rng, rows, max_deg=6):
    degs = rng.integers(0, max_deg, (rows,))
    return jnp.asarray(np.concatenate([[0], np.cumsum(degs)]), jnp.int32)


class TestCamScan:
    @pytest.mark.parametrize("rows,block", [(1, 512), (20, 8), (600, 512)])
    def test_matches_ref(self, rows, block):
        rp = _random_rp(RNG, rows)
        total = int(rp[-1])
        if total == 0:
            pytest.skip("empty graph draw")
        pos = int(RNG.integers(0, total))
        got = cam_scan(rp, pos, block=block)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.cam_scan_ref(rp, pos)))

    def test_exactly_one_owner_for_valid_pos(self):
        rp = jnp.asarray([0, 2, 2, 5, 9], jnp.int32)  # row 1 is empty
        for pos in range(9):
            got = cam_scan(rp, pos)
            assert int(jnp.sum(got)) == 1, f"pos={pos}"
            owner = int(jnp.argmax(got))
            assert int(rp[owner]) <= pos < int(rp[owner + 1])

    def test_empty_rows_never_fire(self):
        rp = jnp.asarray([0, 3, 3, 6], jnp.int32)
        for pos in range(6):
            assert int(cam_scan(rp, pos)[1]) == 0

    def test_out_of_range_pos_fires_nothing(self):
        rp = jnp.asarray([0, 2, 4], jnp.int32)
        assert int(jnp.sum(cam_scan(rp, 4))) == 0
        assert int(jnp.sum(cam_scan(rp, -1))) == 0

    def test_rejects_too_short(self):
        with pytest.raises(ValueError):
            cam_scan(jnp.asarray([0], jnp.int32), 0)


@settings(max_examples=20, deadline=None)
@given(rows=st.integers(1, 200), seed=st.integers(0, 2**31 - 1), block=st.sampled_from([8, 64, 512]))
def test_hypothesis_scan_owner_invariant(rows, seed, block):
    """For every valid edge position exactly one CSR row owns it (paper Fig 3d)."""
    rng = np.random.default_rng(seed)
    rp = _random_rp(rng, rows)
    total = int(rp[-1])
    if total == 0:
        return
    pos = int(rng.integers(0, total))
    got = cam_scan(rp, pos, block=block)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.cam_scan_ref(rp, pos)))
    assert int(jnp.sum(got)) == 1
