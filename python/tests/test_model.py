"""Layer-2 GCN model: shape contracts, crossbar-vs-exact agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref
from compile.model import GcnConfig, Gcn2Params, gcn2_forward, gcn_layer, init_gcn2

CFG = GcnConfig(batch=8, sample=4, feature=48, hidden=16, classes=5, table=32)
RNG = np.random.default_rng(3)


def _inputs(cfg):
    x_self = jnp.asarray(RNG.normal(size=(cfg.batch, cfg.feature)), jnp.float32)
    idx = jnp.asarray(RNG.integers(-1, cfg.table, (cfg.batch, cfg.sample)), jnp.int32)
    x_table = jnp.asarray(RNG.normal(size=(cfg.table, cfg.feature)), jnp.float32)
    return x_self, idx, x_table


class TestGcnLayer:
    def test_output_shape(self):
        x_self, idx, x_table = _inputs(CFG)
        w = jnp.asarray(RNG.normal(size=(CFG.feature, CFG.hidden)), jnp.float32)
        out = gcn_layer(CFG, x_self, idx, x_table, w)
        assert out.shape == (CFG.batch, CFG.hidden)

    def test_exact_mode_matches_oracle(self):
        cfg = CFG._replace(use_crossbar=False)
        x_self, idx, x_table = _inputs(cfg)
        w = jnp.asarray(RNG.normal(size=(cfg.feature, cfg.hidden)), jnp.float32)
        got = gcn_layer(cfg, x_self, idx, x_table, w)
        want = ref.gcn_layer_ref(x_self, idx, x_table, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)

    def test_crossbar_mode_tracks_exact(self):
        x_self, idx, x_table = _inputs(CFG)
        w = jnp.asarray(RNG.normal(size=(CFG.feature, CFG.hidden)), jnp.float32)
        approx = gcn_layer(CFG, x_self, idx, x_table, w)
        exact = gcn_layer(CFG._replace(use_crossbar=False), x_self, idx, x_table, w)
        denom = float(jnp.max(jnp.abs(exact))) + 1e-9
        rel = float(jnp.max(jnp.abs(approx - exact))) / denom
        assert rel < 0.4, f"crossbar quantization error too large: {rel}"
        # ...and correlation should be strong (signal preserved).
        a, e = np.asarray(approx).ravel(), np.asarray(exact).ravel()
        assert np.corrcoef(a, e)[0, 1] > 0.95

    def test_relu_applied(self):
        x_self, idx, x_table = _inputs(CFG)
        w = jnp.asarray(RNG.normal(size=(CFG.feature, CFG.hidden)), jnp.float32)
        out = gcn_layer(CFG, x_self, idx, x_table, w, activate=True)
        assert float(jnp.min(out)) >= 0.0


class TestGcn2:
    def test_forward_shape_and_jit(self):
        cfg = CFG
        params = init_gcn2(cfg, jax.random.PRNGKey(0))
        x_self, idx, x_table = _inputs(cfg)
        h_table = jnp.asarray(RNG.normal(size=(cfg.table, cfg.hidden)), jnp.float32)
        out = jax.jit(
            lambda *a: gcn2_forward(cfg, *a)
        )(x_self, idx, x_table, h_table, params.w1, params.w2)
        assert out.shape == (cfg.batch, cfg.classes)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_deterministic(self):
        cfg = CFG._replace(use_crossbar=False)
        params = init_gcn2(cfg, jax.random.PRNGKey(1))
        x_self, idx, x_table = _inputs(cfg)
        h_table = jnp.zeros((cfg.table, cfg.hidden), jnp.float32)
        a = gcn2_forward(cfg, x_self, idx, x_table, h_table, params.w1, params.w2)
        b = gcn2_forward(cfg, x_self, idx, x_table, h_table, params.w1, params.w2)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_init_shapes(self):
        params = init_gcn2(CFG, jax.random.PRNGKey(0))
        assert params.w1.shape == (CFG.feature, CFG.hidden)
        assert params.w2.shape == (CFG.hidden, CFG.classes)
