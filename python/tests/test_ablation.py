"""Quantization ablations on the crossbar kernel: the accuracy knobs the
hardware design trades against (ADC resolution, weight levels, DAC bits,
crossbar rows)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import crossbar_linear, crossbar_mvm
from compile.kernels import ref

RNG = np.random.default_rng(77)


def _xw(m=8, k=256, n=16):
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    return x, w


def _err(y, exact):
    return float(jnp.mean(jnp.abs(y - exact)) / (jnp.mean(jnp.abs(exact)) + 1e-9))


class TestAdcResolution:
    def test_error_decreases_with_adc_bits(self):
        x, w = _xw()
        exact = x @ w
        errs = []
        for adc_bits in (5, 7, 9, 13):
            y = crossbar_linear(x, w, adc_bits=adc_bits, xbar_rows=128)
            errs.append(_err(y, exact))
        assert errs[0] > errs[-1], f"tight ADC must hurt: {errs}"
        # monotone within tolerance (quantization noise can tie)
        for a, b in zip(errs, errs[1:]):
            assert b <= a * 1.10, f"non-monotone ADC sweep: {errs}"

    def test_lossless_adc_threshold(self):
        # 128 rows x 1-bit plane x |w|<=8 needs ceil(log2(128*8))+1 = 11 bits.
        xq = jnp.asarray(RNG.integers(0, 256, (4, 128)), jnp.int32)
        gq = jnp.asarray(RNG.integers(-8, 8, (128, 8)), jnp.int32)
        lossless = crossbar_mvm(xq, gq, adc_bits=11, xbar_rows=128)
        np.testing.assert_array_equal(np.asarray(lossless), np.asarray(xq @ gq))


class TestWeightLevels:
    @pytest.mark.parametrize("pair", [(2, 4), (4, 6)])
    def test_more_levels_less_error(self, pair):
        lo, hi = pair
        x, w = _xw()
        exact = x @ w
        e_lo = _err(crossbar_linear(x, w, weight_bits=lo), exact)
        e_hi = _err(crossbar_linear(x, w, weight_bits=hi), exact)
        assert e_hi < e_lo


class TestDacBits:
    def test_more_input_bits_less_error(self):
        x, w = _xw()
        exact = x @ w
        e4 = _err(crossbar_linear(x, w, input_bits=4), exact)
        e8 = _err(crossbar_linear(x, w, input_bits=8), exact)
        assert e8 < e4

    def test_one_bit_dac_still_correlates(self):
        x, w = _xw()
        y = crossbar_linear(x, w, input_bits=1)
        exact = x @ w
        corr = np.corrcoef(np.asarray(y).ravel(), np.asarray(exact).ravel())[0, 1]
        assert corr > 0.7


class TestCrossbarRows:
    def test_row_partitioning_is_invariant_when_lossless(self):
        # With a lossless ADC the K-tiling must not change the result.
        xq = jnp.asarray(RNG.integers(0, 256, (5, 384)), jnp.int32)
        gq = jnp.asarray(RNG.integers(-8, 8, (384, 12)), jnp.int32)
        outs = [
            np.asarray(crossbar_mvm(xq, gq, xbar_rows=r, adc_bits=20))
            for r in (64, 128, 384)
        ]
        np.testing.assert_array_equal(outs[0], outs[1])
        np.testing.assert_array_equal(outs[1], outs[2])

    def test_smaller_arrays_clip_less_under_tight_adc(self):
        # Tight ADC: smaller crossbars saturate less (fewer rows per sum),
        # so they track the true product better.
        xq = jnp.asarray(RNG.integers(0, 256, (5, 512)), jnp.int32)
        gq = jnp.asarray(RNG.integers(0, 8, (512, 12)), jnp.int32)  # all-positive worst case
        exact = np.asarray(xq @ gq, dtype=np.float64)
        def err(rows):
            y = np.asarray(crossbar_mvm(xq, gq, xbar_rows=rows, adc_bits=8), dtype=np.float64)
            return np.mean(np.abs(y - exact))
        assert err(64) < err(512)
