"""AOT lowering: artifacts are valid HLO text and the manifest is complete."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    # Only the small artifact: fast enough for CI, exercises the full path.
    entries = aot.build(str(out), only=["gcn_layer_small"], verbose=False)
    return out, entries


class TestAotBuild:
    def test_writes_hlo_text(self, built):
        out, entries = built
        assert len(entries) == 1
        path = out / entries[0]["file"]
        text = path.read_text()
        assert text.startswith("HloModule"), "artifact must be HLO text"
        assert "ENTRY" in text

    def test_manifest_structure(self, built):
        out, entries = built
        manifest = json.loads((out / "manifest.json").read_text())
        assert manifest["version"] == 1
        art = manifest["artifacts"][0]
        assert art["name"] == "gcn_layer_small"
        assert art["file"] == "gcn_layer_small.hlo.txt"
        # gcn_layer_fn takes (x_self, nbr_idx, x_table, w).
        assert len(art["inputs"]) == 4
        assert art["inputs"][0]["dtype"] == "float32"
        assert art["inputs"][1]["dtype"] == "int32"
        assert len(art["outputs"]) == 1
        assert art["outputs"][0]["shape"] == [16, 32]

    def test_config_recorded(self, built):
        _, entries = built
        cfg = entries[0]["config"]
        assert cfg["feature"] == 64 and cfg["hidden"] == 32

    def test_registry_names_are_unique_files(self):
        reg = aot._registry()
        files = [f"{name}.hlo.txt" for name in reg]
        assert len(set(files)) == len(files)
        # Registry contains everything DESIGN.md promises.
        for required in ("gcn2_cora", "hetgnn_taxi", "mvm_512x512", "gcn_layer_small"):
            assert required in reg
