"""hetGNN-LSTM taxi model (paper Fig. 7): shapes, determinism, sensitivity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.hetgnn import (
    EDGE_TYPES,
    HetGnnConfig,
    hetgnn_forward,
    init_hetgnn,
)

CFG = HetGnnConfig(
    batch=4, sample=3, table=16, grid_m=4, grid_n=4, hist=5, horizon=2, hidden=8,
    use_crossbar=False,
)
RNG = np.random.default_rng(11)


def _inputs(cfg):
    x = jnp.asarray(RNG.normal(size=(cfg.batch, cfg.hist, cfg.fin)), jnp.float32)
    idx = jnp.asarray(
        RNG.integers(-1, cfg.table, (cfg.batch, EDGE_TYPES, cfg.sample)), jnp.int32
    )
    table = jnp.asarray(RNG.normal(size=(cfg.table, cfg.hist, cfg.hidden)), jnp.float32)
    return x, idx, table


class TestHetGnn:
    def test_fin(self):
        assert CFG.fin == 2 * 4 * 4

    def test_forward_shape(self):
        params = init_hetgnn(CFG, jax.random.PRNGKey(0))
        x, idx, table = _inputs(CFG)
        y = hetgnn_forward(CFG, params, x, idx, table)
        assert y.shape == (CFG.batch, CFG.horizon, CFG.fin)
        assert bool(jnp.all(jnp.isfinite(y)))

    def test_deterministic(self):
        params = init_hetgnn(CFG, jax.random.PRNGKey(0))
        x, idx, table = _inputs(CFG)
        a = hetgnn_forward(CFG, params, x, idx, table)
        b = hetgnn_forward(CFG, params, x, idx, table)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_depends_on_history(self):
        params = init_hetgnn(CFG, jax.random.PRNGKey(0))
        x, idx, table = _inputs(CFG)
        y1 = hetgnn_forward(CFG, params, x, idx, table)
        y2 = hetgnn_forward(CFG, params, x + 1.0, idx, table)
        assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-6

    def test_depends_on_neighbors(self):
        params = init_hetgnn(CFG, jax.random.PRNGKey(0))
        x, idx, table = _inputs(CFG)
        y1 = hetgnn_forward(CFG, params, x, idx, table)
        y2 = hetgnn_forward(CFG, params, x, idx, table * 2.0)
        assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-6

    def test_isolated_node_ignores_table(self):
        params = init_hetgnn(CFG, jax.random.PRNGKey(0))
        x, _, table = _inputs(CFG)
        idx = jnp.full((CFG.batch, EDGE_TYPES, CFG.sample), -1, jnp.int32)
        y1 = hetgnn_forward(CFG, params, x, idx, table)
        y2 = hetgnn_forward(CFG, params, x, idx, table * 5.0)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-6, atol=1e-6)

    def test_crossbar_mode_tracks_exact(self):
        cfg_q = CFG._replace(use_crossbar=True)
        params = init_hetgnn(CFG, jax.random.PRNGKey(2))
        x, idx, table = _inputs(CFG)
        exact = hetgnn_forward(CFG, params, x, idx, table)
        approx = hetgnn_forward(cfg_q, params, x, idx, table)
        a, e = np.asarray(approx).ravel(), np.asarray(exact).ravel()
        assert np.corrcoef(a, e)[0, 1] > 0.9

    def test_jit_compiles(self):
        params = init_hetgnn(CFG, jax.random.PRNGKey(0))
        x, idx, table = _inputs(CFG)
        y = jax.jit(lambda *a: hetgnn_forward(CFG, params, *a))(x, idx, table)
        assert y.shape == (CFG.batch, CFG.horizon, CFG.fin)

    def test_init_param_shapes(self):
        p = init_hetgnn(CFG, jax.random.PRNGKey(0))
        h = CFG.hidden
        assert p.w_embed.shape == (CFG.fin, h)
        assert p.w_msg.shape == (EDGE_TYPES, h, h)
        assert p.w_i.shape == (h, 4 * h)
        assert p.w_h.shape == (h, 4 * h)
        assert p.w_out.shape == (h, CFG.horizon * CFG.fin)
