"""AOT entrypoint: lower every Layer-2 model to an HLO-text artifact.

Python runs ONCE, at build time (``make artifacts``); the rust coordinator
loads the emitted ``artifacts/*.hlo.txt`` through PJRT and never imports
Python again.

Interchange format is HLO *text*, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/load_hlo and DESIGN.md.

Every artifact is described in ``artifacts/manifest.json`` (name, file,
input/output shapes and dtypes, model config) which the rust
``runtime::manifest`` module parses.
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .hetgnn import HetGnnConfig, hetgnn_fn
from .model import GcnConfig, gcn2_fn, gcn_layer_fn, mvm_fn


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# Registry of artifacts: name -> (builder returning (fn, example_args), config dict)
def _registry() -> Dict[str, Tuple[Callable, dict]]:
    # Quickstart: tiny single GCN layer.
    small = GcnConfig(batch=16, sample=4, feature=64, hidden=32, classes=8, table=64)
    # Dataset study: Cora-shaped 2-layer GCN over sampled subgraphs
    # (feature length 1433 / 7 classes, Table 2).
    cora = GcnConfig(batch=64, sample=8, feature=1433, hidden=64, classes=7, table=256)
    cora_exact = cora._replace(use_crossbar=False)
    # Citeseer-shaped single layer for the decentralized per-device path.
    citeseer = GcnConfig(
        batch=32, sample=4, feature=3703, hidden=64, classes=6, table=128
    )
    taxi = HetGnnConfig()

    return {
        "gcn_layer_small": (lambda: gcn_layer_fn(small), small._asdict()),
        "gcn2_cora": (lambda: gcn2_fn(cora), cora._asdict()),
        "gcn2_cora_exact": (lambda: gcn2_fn(cora_exact), cora_exact._asdict()),
        "gcn_layer_citeseer": (lambda: gcn_layer_fn(citeseer), citeseer._asdict()),
        "hetgnn_taxi": (lambda: hetgnn_fn(taxi), taxi._asdict()),
        "mvm_512x512": (lambda: mvm_fn(512, 512, batch=8), {"rows": 512, "cols": 512, "batch": 8}),
    }


def _spec_dict(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(jnp.dtype(s.dtype).name)}


def build(out_dir: str, only: Sequence[str] | None = None, verbose: bool = True) -> List[dict]:
    os.makedirs(out_dir, exist_ok=True)
    entries: List[dict] = []
    for name, (builder, cfg) in _registry().items():
        if only and name not in only:
            continue
        fn, example_args = builder()
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *example_args)
        entries.append(
            {
                "name": name,
                "file": fname,
                "inputs": [_spec_dict(a) for a in example_args],
                "outputs": [_spec_dict(o) for o in out_specs],
                "config": {k: (v if not isinstance(v, bool) else int(v)) for k, v in cfg.items()},
            }
        )
        if verbose:
            print(f"  lowered {name}: {len(text)} chars, {len(example_args)} inputs")
    manifest = {"version": 1, "artifacts": entries}
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    if verbose:
        print(f"wrote {len(entries)} artifacts + manifest.json to {out_dir}")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument("--only", nargs="*", default=None, help="subset of artifact names")
    args = ap.parse_args()
    build(args.out, args.only)


if __name__ == "__main__":
    main()
