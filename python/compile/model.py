"""Layer-2 JAX model: the GNN compute graph of paper Fig. 1.

One GNN layer = *aggregation* (the Z matrix: destination features combined
with sampled neighbor features) followed by *feature extraction*
(``O = sigma(Z @ W)``) -- exactly the two IMA-GNN compute cores.  The dense
transforms route through the Layer-1 crossbar kernel so the whole model
lowers into a single HLO module containing the emulated-crossbar dataflow.

The module is lowered once by ``aot.py``; Python never runs at inference
time -- the rust coordinator executes the HLO artifact through PJRT.
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Sequence

import jax
import jax.numpy as jnp

from .kernels import crossbar_linear, gather_mean


class GcnConfig(NamedTuple):
    """Static shape/quantization configuration for a sampled-subgraph GCN."""

    batch: int  # destination nodes per request (B)
    sample: int  # fixed-size uniform neighbor sample (S), paper §4.3
    feature: int  # input feature length (F), Table 2
    hidden: int  # hidden width (H)
    classes: int  # output classes (C)
    table: int  # rows of the neighbor-feature table shipped per batch
    input_bits: int = 8
    weight_bits: int = 4
    adc_bits: int = 13
    xbar_rows: int = 512
    use_crossbar: bool = True  # False = exact f32 matmuls (ablation)


def _linear(cfg: GcnConfig, x: jax.Array, w: jax.Array) -> jax.Array:
    if cfg.use_crossbar:
        return crossbar_linear(
            x,
            w,
            input_bits=cfg.input_bits,
            weight_bits=cfg.weight_bits,
            adc_bits=cfg.adc_bits,
            xbar_rows=cfg.xbar_rows,
        )
    return x @ w


def gcn_layer(
    cfg: GcnConfig,
    x_self: jax.Array,
    nbr_idx: jax.Array,
    x_table: jax.Array,
    w: jax.Array,
    *,
    activate: bool = True,
) -> jax.Array:
    """One IMA-GNN layer over a sampled subgraph.

    ``x_self [B, Fin]``: destination node features;
    ``nbr_idx [B, S]``: sampled neighbor rows into ``x_table`` (-1 = pad);
    ``x_table [T, Fin]``: neighbor feature table;
    ``w [Fin, Fout]``: layer weights.
    """
    # Aggregation core: node-stationary gather + combine with self.
    z = 0.5 * (x_self + gather_mean(x_table, nbr_idx))
    # Feature-extraction core: MVM crossbar + activation unit.
    o = _linear(cfg, z, w)
    return jax.nn.relu(o) if activate else o


class Gcn2Params(NamedTuple):
    w1: jax.Array  # [F, H]
    w2: jax.Array  # [H, C]


def init_gcn2(cfg: GcnConfig, key: jax.Array) -> Gcn2Params:
    """Glorot-uniform initialization of the 2-layer GCN."""
    k1, k2 = jax.random.split(key)
    lim1 = (6.0 / (cfg.feature + cfg.hidden)) ** 0.5
    lim2 = (6.0 / (cfg.hidden + cfg.classes)) ** 0.5
    return Gcn2Params(
        w1=jax.random.uniform(k1, (cfg.feature, cfg.hidden), jnp.float32, -lim1, lim1),
        w2=jax.random.uniform(k2, (cfg.hidden, cfg.classes), jnp.float32, -lim2, lim2),
    )


def gcn2_forward(
    cfg: GcnConfig,
    x_self: jax.Array,
    nbr_idx: jax.Array,
    x_table: jax.Array,
    h_table: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
) -> jax.Array:
    """Two-layer GCN over a sampled 2-hop subgraph.

    Layer 1 consumes raw features; layer 2 consumes the hidden table
    ``h_table [T, H]`` (the layer-1 embeddings of the sampled 1-hop
    frontier, produced by the same artifact on the previous round or
    shipped by the coordinator).  Returns class logits ``[B, C]``.
    """
    h_self = gcn_layer(cfg, x_self, nbr_idx, x_table, w1, activate=True)
    logits = gcn_layer(cfg, h_self, nbr_idx, h_table, w2, activate=False)
    return logits


def gcn2_fn(cfg: GcnConfig):
    """Callable + example args for AOT lowering of the 2-layer GCN."""

    def fn(x_self, nbr_idx, x_table, h_table, w1, w2):
        return (gcn2_forward(cfg, x_self, nbr_idx, x_table, h_table, w1, w2),)

    args = (
        jax.ShapeDtypeStruct((cfg.batch, cfg.feature), jnp.float32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.sample), jnp.int32),
        jax.ShapeDtypeStruct((cfg.table, cfg.feature), jnp.float32),
        jax.ShapeDtypeStruct((cfg.table, cfg.hidden), jnp.float32),
        jax.ShapeDtypeStruct((cfg.feature, cfg.hidden), jnp.float32),
        jax.ShapeDtypeStruct((cfg.hidden, cfg.classes), jnp.float32),
    )
    return fn, args


def gcn_layer_fn(cfg: GcnConfig):
    """Single-layer artifact (used by the decentralized per-device path)."""

    def fn(x_self, nbr_idx, x_table, w):
        return (gcn_layer(cfg, x_self, nbr_idx, x_table, w, activate=True),)

    args = (
        jax.ShapeDtypeStruct((cfg.batch, cfg.feature), jnp.float32),
        jax.ShapeDtypeStruct((cfg.batch, cfg.sample), jnp.int32),
        jax.ShapeDtypeStruct((cfg.table, cfg.feature), jnp.float32),
        jax.ShapeDtypeStruct((cfg.feature, cfg.hidden), jnp.float32),
    )
    return fn, args


def mvm_fn(rows: int, cols: int, batch: int = 1, xbar_rows: int = 512):
    """Raw crossbar-MVM artifact for runtime microbenchmarks."""
    from .kernels import crossbar_mvm

    def fn(xq, gq):
        return (crossbar_mvm(xq, gq, xbar_rows=xbar_rows),)

    args = (
        jax.ShapeDtypeStruct((batch, rows), jnp.int32),
        jax.ShapeDtypeStruct((rows, cols), jnp.int32),
    )
    return fn, args
