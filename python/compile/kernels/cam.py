"""Resistive CAM crossbar ops of the IMA-GNN traversal core (paper Fig. 3).

Two TCAM operations are modeled:

* ``cam_search`` -- the *search CAM*: every stored key row performs an XNOR
  match against the query on its match-line; rows equal to the query fire.
  In the paper the stored keys are the CSR Column-Index (CI) array and the
  query is a destination node id (Fig. 3(c)).

* ``cam_scan`` -- the *scan CAM* compare operation: bit-lines are driven
  with calibrated increasing voltages so each row reports an order
  comparison rather than equality.  Given the CSR Row-Pointer (RP) array it
  locates, for an edge position ``pos``, the owning source row ``i`` with
  ``RP[i] <= pos < RP[i+1]`` (Fig. 3(d)).

Both are Pallas kernels (interpret=True) over int32 lanes; a match-line is
emulated as a 0/1 int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _search_kernel(q_ref, keys_ref, o_ref):
    # XNOR match: a row fires iff every cell matches, i.e. key == query.
    q = q_ref[0]
    o_ref[...] = (keys_ref[...] == q).astype(jnp.int32)


def cam_search(
    keys: jax.Array, query: jax.Array, *, block: int = 512, interpret: bool = True
) -> jax.Array:
    """Match-line vector: ``out[i] = 1`` iff ``keys[i] == query``.

    ``keys`` is int32 ``[N]`` (the CI array), ``query`` an int32 scalar.
    """
    if keys.ndim != 1:
        raise ValueError(f"keys must be 1-D, got shape {keys.shape}")
    n = keys.shape[0]
    b = min(block, n)
    pad = (-n) % b
    # Pad with an impossible key so padding rows never match.
    keys_p = jnp.pad(keys, (0, pad), constant_values=-1)
    q = jnp.asarray(query, jnp.int32).reshape((1,))
    out = pl.pallas_call(
        _search_kernel,
        grid=((n + pad) // b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.int32),
        interpret=interpret,
    )(q, keys_p)
    return out[:n]


def _scan_kernel(pos_ref, rp_ref, rp_next_ref, o_ref):
    # Compare operation: calibrated voltages realize `<=` / `<` thresholds.
    p = pos_ref[0]
    rp = rp_ref[...]
    rp_next = rp_next_ref[...]
    o_ref[...] = ((rp <= p) & (p < rp_next)).astype(jnp.int32)


def cam_scan(
    rp: jax.Array, pos: jax.Array, *, block: int = 512, interpret: bool = True
) -> jax.Array:
    """Owning-row one-hot: ``out[i] = 1`` iff ``rp[i] <= pos < rp[i+1]``.

    ``rp`` is the CSR row-pointer array ``[R+1]`` (int32); the result has
    shape ``[R]``.  For a valid CSR pointer array and ``0 <= pos < rp[-1]``
    exactly one row fires.
    """
    if rp.ndim != 1 or rp.shape[0] < 2:
        raise ValueError(f"rp must be 1-D with >= 2 entries, got {rp.shape}")
    r = rp.shape[0] - 1
    lo = rp[:-1]
    hi = rp[1:]
    b = min(block, r)
    pad = (-r) % b
    # Pad with an empty range so padding rows never fire.
    lo_p = jnp.pad(lo, (0, pad), constant_values=-1)
    hi_p = jnp.pad(hi, (0, pad), constant_values=-1)
    p = jnp.asarray(pos, jnp.int32).reshape((1,))
    out = pl.pallas_call(
        _scan_kernel,
        grid=((r + pad) // b,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r + pad,), jnp.int32),
        interpret=interpret,
    )(p, lo_p, hi_p)
    return out[:r]
