"""Pure-jnp oracles for every Layer-1 kernel.

These are the correctness ground truth: integer paths must match
bit-exactly, float paths to allclose tolerance.  No Pallas imports here --
the point is an independent implementation of the same semantics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .mvm_crossbar import (
    DEFAULT_ADC_BITS,
    DEFAULT_INPUT_BITS,
    DEFAULT_XBAR_ROWS,
    dequantize,
    quantize_inputs,
    quantize_weights,
)


def crossbar_mvm_ref(
    xq: jax.Array,
    gq: jax.Array,
    *,
    input_bits: int = DEFAULT_INPUT_BITS,
    adc_bits: int = DEFAULT_ADC_BITS,
    xbar_rows: int = DEFAULT_XBAR_ROWS,
) -> jax.Array:
    """Bit-serial crossbar MVM with per-crossbar, per-bit-plane ADC clip."""
    m, k = xq.shape
    _, n = gq.shape
    lo = -(1 << (adc_bits - 1))
    hi = (1 << (adc_bits - 1)) - 1
    out = jnp.zeros((m, n), jnp.int32)
    for k0 in range(0, k, xbar_rows):
        xs = xq[:, k0 : k0 + xbar_rows]
        gs = gq[k0 : k0 + xbar_rows, :]
        acc = jnp.zeros((m, n), jnp.int32)
        for b in range(input_bits):
            plane = (xs >> b) & 1
            ps = jnp.clip(plane @ gs, lo, hi)
            acc = acc + (ps << b)
        out = out + acc
    return out


def crossbar_linear_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    input_bits: int = DEFAULT_INPUT_BITS,
    weight_bits: int = 4,
    adc_bits: int = DEFAULT_ADC_BITS,
    xbar_rows: int = DEFAULT_XBAR_ROWS,
) -> jax.Array:
    gq, w_scale = quantize_weights(w, weight_bits)
    xq, x_scale, x_zero = quantize_inputs(x, input_bits)
    acc = crossbar_mvm_ref(
        xq, gq, input_bits=input_bits, adc_bits=adc_bits, xbar_rows=xbar_rows
    )
    colsum = jnp.sum(gq.astype(jnp.float32), axis=0)
    return dequantize(acc, x_scale, x_zero, w_scale, colsum)


def cam_search_ref(keys: jax.Array, query) -> jax.Array:
    return (keys == jnp.asarray(query, keys.dtype)).astype(jnp.int32)


def cam_scan_ref(rp: jax.Array, pos) -> jax.Array:
    p = jnp.asarray(pos, rp.dtype)
    return ((rp[:-1] <= p) & (p < rp[1:])).astype(jnp.int32)


def gather_sum_ref(x: jax.Array, idx: jax.Array) -> jax.Array:
    n, f = x.shape
    xz = jnp.concatenate([x, jnp.zeros((1, f), x.dtype)], axis=0)
    idx_safe = jnp.where(idx < 0, n, idx)
    return jnp.sum(jnp.take(xz, idx_safe, axis=0), axis=1)


def gather_mean_ref(x: jax.Array, idx: jax.Array) -> jax.Array:
    total = gather_sum_ref(x, idx)
    count = jnp.maximum(jnp.sum((idx >= 0).astype(jnp.float32), axis=1, keepdims=True), 1.0)
    return (total.astype(jnp.float32) / count).astype(x.dtype)


def gcn_layer_ref(
    x_self: jax.Array, x_nbrs_idx: jax.Array, x_table: jax.Array, w: jax.Array
) -> jax.Array:
    """Float oracle of one GCN layer: mean-aggregate then transform+ReLU."""
    z = 0.5 * (x_self + gather_mean_ref(x_table, x_nbrs_idx))
    return jax.nn.relu(z @ w)
