"""Bit-serial, conductance-quantized crossbar MVM as a Pallas kernel.

This is the compute hot-spot of IMA-GNN's aggregation and feature-extraction
cores (paper Fig. 2(b)).  The analog dataflow is reproduced digitally,
bit-exactly reproducible against the pure-jnp oracle in ``ref.py``:

  1. weights are quantized to signed RRAM conductance levels
     (``weight_bits``, default 4 -> levels in [-8, 7]);
  2. inputs are affine-quantized to unsigned ``input_bits`` integers
     (the DAC applies one bit per cycle on the bit-lines);
  3. for every input bit-plane, each crossbar column accumulates the
     weighted currents of its rows -- an integer (plane @ G) matmul per
     K-tile of ``xbar_rows`` rows (one physical crossbar);
  4. the per-column analog sum is sampled and ADC-quantized: values are
     clipped to the signed ``adc_bits`` range *per crossbar, per bit-plane*
     -- exactly where the paper's Sample&Hold + ADC sit;
  5. Shift & Add recombines the bit-plane partial products, and partial
     sums from K-tiles (crossbars sharing an output column) are added
     digitally.

Hardware adaptation (DESIGN.md §3): a crossbar holds a weight tile
stationary and streams inputs; here ``BlockSpec`` pins the quantized weight
block in VMEM while the grid streams (M, N, K) tiles -- the HBM<->VMEM
schedule standing in for the paper's buffer array + double buffering, and
the MXU matmul per bit-plane standing in for the analog dot-product plane.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_WEIGHT_BITS = 4
DEFAULT_INPUT_BITS = 8
# 512 active rows x 4-bit weights need ceil(log2(512*8)) + 1 = 13 signed bits
# for a loss-free ADC; smaller ADCs clip (supported, tested).
DEFAULT_ADC_BITS = 13
DEFAULT_XBAR_ROWS = 512


def quantize_weights(
    w: jax.Array, weight_bits: int = DEFAULT_WEIGHT_BITS
) -> Tuple[jax.Array, jax.Array]:
    """Symmetric quantization of float weights to conductance levels.

    Returns ``(gq, scale)`` with ``gq`` int32 in ``[-2^(b-1), 2^(b-1)-1]``
    and ``w ~= gq * scale``.
    """
    if weight_bits < 2:
        raise ValueError(f"weight_bits must be >= 2, got {weight_bits}")
    qmax = (1 << (weight_bits - 1)) - 1
    absmax = jnp.maximum(jnp.max(jnp.abs(w)), 1e-12)
    scale = absmax / qmax
    gq = jnp.clip(jnp.round(w / scale), -qmax - 1, qmax).astype(jnp.int32)
    return gq, scale


def quantize_inputs(
    x: jax.Array, input_bits: int = DEFAULT_INPUT_BITS
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Affine quantization of float inputs to unsigned DAC codes.

    Returns ``(xq, scale, zero)`` with ``xq`` int32 in ``[0, 2^bits - 1]``
    and ``x ~= xq * scale + zero``.
    """
    if input_bits < 1:
        raise ValueError(f"input_bits must be >= 1, got {input_bits}")
    qmax = (1 << input_bits) - 1
    xmin = jnp.min(x)
    xmax = jnp.max(x)
    scale = jnp.maximum(xmax - xmin, 1e-12) / qmax
    xq = jnp.clip(jnp.round((x - xmin) / scale), 0, qmax).astype(jnp.int32)
    return xq, scale, xmin


def dequantize(
    acc: jax.Array,
    x_scale: jax.Array,
    x_zero: jax.Array,
    w_scale: jax.Array,
    g_colsum: jax.Array,
) -> jax.Array:
    """Invert the affine/symmetric quantization of ``crossbar_mvm``.

    ``x @ w ~= x_scale * w_scale * acc + x_zero * w_scale * colsum(gq)``.
    """
    return x_scale * w_scale * acc.astype(jnp.float32) + x_zero * w_scale * g_colsum


def _mvm_kernel(x_ref, g_ref, o_ref, *, input_bits: int, adc_bits: int, n_k: int):
    """One (bm, bn) output tile; grid axis 2 streams K-tiles (crossbars)."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]  # [bm, bk] int32, unsigned codes
    g = g_ref[...]  # [bk, bn] int32, signed conductance levels
    lo = -(1 << (adc_bits - 1))
    hi = (1 << (adc_bits - 1)) - 1
    acc = jnp.zeros(o_ref.shape, jnp.int32)
    # DAC bit-serial streaming: one input bit-plane per cycle.  The python
    # loop unrolls (input_bits is static) into input_bits MXU matmuls.
    for b in range(input_bits):
        plane = (x >> b) & 1
        # Analog per-column accumulation of one crossbar (this K-tile).
        ps = jax.lax.dot_general(
            plane,
            g,
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
        # Sample & Hold + ADC: clip to the converter range.
        ps = jnp.clip(ps, lo, hi)
        # Shift & Add unit.
        acc = acc + (ps << b)
    # Digital partial-sum combine across crossbars sharing this column.
    o_ref[...] += acc


def crossbar_mvm(
    xq: jax.Array,
    gq: jax.Array,
    *,
    input_bits: int = DEFAULT_INPUT_BITS,
    adc_bits: int = DEFAULT_ADC_BITS,
    xbar_rows: int = DEFAULT_XBAR_ROWS,
    block_m: int = 128,
    block_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Integer crossbar MVM: ``xq [M,K] @ gq [K,N] -> int32 [M,N]``.

    ``xq`` must hold unsigned codes of ``input_bits`` bits; ``gq`` signed
    conductance levels.  The ADC clip is applied per K-tile of ``xbar_rows``
    rows and per input bit-plane, matching the analog array boundary.
    """
    if xq.ndim != 2 or gq.ndim != 2:
        raise ValueError(f"expected 2-D operands, got {xq.shape} @ {gq.shape}")
    m, k = xq.shape
    k2, n = gq.shape
    if k != k2:
        raise ValueError(f"contraction mismatch: {xq.shape} @ {gq.shape}")

    bm = min(block_m, m)
    bn = min(block_n, n)
    bk = min(xbar_rows, k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    # Zero padding is exact: zero input codes contribute zero current and
    # zero conductance rows contribute zero weight.
    xp = jnp.pad(xq, ((0, pm), (0, pk)))
    gp = jnp.pad(gq, ((0, pk), (0, pn)))
    grid = ((m + pm) // bm, (n + pn) // bn, (k + pk) // bk)

    out = pl.pallas_call(
        functools.partial(
            _mvm_kernel, input_bits=input_bits, adc_bits=adc_bits, n_k=grid[2]
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m + pm, n + pn), jnp.int32),
        interpret=interpret,
    )(xp, gp)
    return out[:m, :n]


def crossbar_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    input_bits: int = DEFAULT_INPUT_BITS,
    weight_bits: int = DEFAULT_WEIGHT_BITS,
    adc_bits: int = DEFAULT_ADC_BITS,
    xbar_rows: int = DEFAULT_XBAR_ROWS,
    interpret: bool = True,
) -> jax.Array:
    """Float linear layer executed on the emulated crossbar.

    Quantize -> bit-serial integer MVM -> dequantize (with zero-point
    correction through the conductance column sums).
    """
    gq, w_scale = quantize_weights(w, weight_bits)
    xq, x_scale, x_zero = quantize_inputs(x, input_bits)
    acc = crossbar_mvm(
        xq,
        gq,
        input_bits=input_bits,
        adc_bits=adc_bits,
        xbar_rows=xbar_rows,
        interpret=interpret,
    )
    colsum = jnp.sum(gq.astype(jnp.float32), axis=0)
    return dequantize(acc, x_scale, x_zero, w_scale, colsum)
