"""Node-stationary neighbor aggregation (paper Fig. 1, aggregation stage).

For every destination node the aggregation core accumulates the features of
its (sampled) source neighbors.  The paper maps a *fixed-size uniform
sample* of each vertex's neighbors (§4.3); the kernels below therefore take
a dense ``[M, S]`` neighbor-index matrix.

The feature table stays stationary (the paper buffers node features in the
buffer array and reuses them across destinations -- node-stationary
dataflow); the grid streams destination blocks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gather_sum_kernel(idx_ref, x_ref, o_ref, *, sample: int):
    idx = idx_ref[...]  # [bm, S] int32
    x = x_ref[...]  # [N, F] feature table (stationary)
    acc = jnp.zeros(o_ref.shape, x.dtype)
    # One buffer-array read per sampled neighbor; S is static so this
    # unrolls into S row-gathers feeding the accumulator.
    for s in range(sample):
        acc = acc + jnp.take(x, idx[:, s], axis=0)
    o_ref[...] = acc


def gather_sum(
    x: jax.Array,
    idx: jax.Array,
    *,
    block_m: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """``out[m] = sum_s x[idx[m, s]]`` -- sum aggregation over samples.

    ``x`` is ``[N, F]`` (float or int), ``idx`` int32 ``[M, S]`` with
    entries in ``[0, N)``.  Entries equal to ``-1`` denote padding
    neighbors and contribute zero.
    """
    if x.ndim != 2 or idx.ndim != 2:
        raise ValueError(f"expected x [N,F] and idx [M,S], got {x.shape}, {idx.shape}")
    n, f = x.shape
    m, s = idx.shape
    # Route padding (-1) neighbors to a zero row appended to the table.
    xz = jnp.concatenate([x, jnp.zeros((1, f), x.dtype)], axis=0)
    idx_safe = jnp.where(idx < 0, n, idx).astype(jnp.int32)

    bm = min(block_m, m)
    pad = (-m) % bm
    idx_p = jnp.pad(idx_safe, ((0, pad), (0, 0)), constant_values=n)
    out = pl.pallas_call(
        functools.partial(_gather_sum_kernel, sample=s),
        grid=((m + pad) // bm,),
        in_specs=[
            pl.BlockSpec((bm, s), lambda i: (i, 0)),
            pl.BlockSpec((n + 1, f), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, f), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m + pad, f), x.dtype),
        interpret=interpret,
    )(idx_p, xz)
    return out[:m]


def gather_mean(
    x: jax.Array,
    idx: jax.Array,
    *,
    block_m: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Mean aggregation over the *valid* (non ``-1``) sampled neighbors."""
    total = gather_sum(x, idx, block_m=block_m, interpret=interpret)
    count = jnp.sum((idx >= 0).astype(jnp.float32), axis=1, keepdims=True)
    count = jnp.maximum(count, 1.0)
    return (total.astype(jnp.float32) / count).astype(x.dtype)
