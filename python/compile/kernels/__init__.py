"""IMA-GNN Layer-1 Pallas kernels.

Every kernel is authored with ``interpret=True`` so it lowers to plain HLO
ops executable by any PJRT backend (the rust CPU client in particular).
Real-TPU lowering would emit Mosaic custom-calls the CPU plugin cannot run;
see DESIGN.md §Hardware-Adaptation for the crossbar->TPU mapping.
"""

from .mvm_crossbar import (
    DEFAULT_ADC_BITS,
    DEFAULT_INPUT_BITS,
    DEFAULT_WEIGHT_BITS,
    DEFAULT_XBAR_ROWS,
    crossbar_linear,
    crossbar_mvm,
    dequantize,
    quantize_inputs,
    quantize_weights,
)
from .cam import cam_scan, cam_search
from .aggregate import gather_mean, gather_sum

__all__ = [
    "DEFAULT_ADC_BITS",
    "DEFAULT_INPUT_BITS",
    "DEFAULT_WEIGHT_BITS",
    "DEFAULT_XBAR_ROWS",
    "cam_scan",
    "cam_search",
    "crossbar_linear",
    "crossbar_mvm",
    "dequantize",
    "gather_mean",
    "gather_sum",
    "quantize_inputs",
    "quantize_weights",
]
