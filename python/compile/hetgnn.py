"""Layer-2 JAX model for the taxi case study (paper §4.2, Fig. 7).

The hetGNN-LSTM of paper ref [26]: heterogeneous message passing over the
three taxi-graph edge types (road connectivity, location proximity,
destination similarity), an LSTM capturing time dependency over the P
historical frames, and a prediction head emitting the Q future
demand/supply frames for the node's surrounding m x n region.

Dense transforms (embedding, per-edge-type message weights, output head)
route through the Layer-1 crossbar kernel -- these are what the
feature-extraction core executes; the LSTM recurrence stays in float (the
recurrent state is held digitally in the buffer array, not in RRAM).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import crossbar_linear, gather_mean

EDGE_TYPES = 3  # road / proximity / destination-similarity


class HetGnnConfig(NamedTuple):
    """Static shapes for the hetGNN-LSTM taxi model."""

    batch: int = 32  # taxi nodes per request (B)
    sample: int = 8  # neighbors sampled per edge type (S)
    table: int = 256  # neighbor embedding table rows (T)
    grid_m: int = 8  # region rows (m)
    grid_n: int = 8  # region cols (n)
    hist: int = 12  # history length (P)
    horizon: int = 3  # prediction length (Q)
    hidden: int = 64  # embedding + LSTM width (H)
    input_bits: int = 8
    weight_bits: int = 4
    adc_bits: int = 13
    xbar_rows: int = 512
    use_crossbar: bool = True

    @property
    def fin(self) -> int:
        """Per-frame feature length: demand + supply over the m x n grid."""
        return 2 * self.grid_m * self.grid_n


class HetGnnParams(NamedTuple):
    w_embed: jax.Array  # [Fin, H]
    w_msg: jax.Array  # [EDGE_TYPES, H, H]
    w_i: jax.Array  # [H, 4H]  LSTM input-to-hidden
    w_h: jax.Array  # [H, 4H]  LSTM hidden-to-hidden
    b: jax.Array  # [4H]
    w_out: jax.Array  # [H, Q * Fin]


def init_hetgnn(cfg: HetGnnConfig, key: jax.Array) -> HetGnnParams:
    ks = jax.random.split(key, 6)

    def glorot(k, shape):
        lim = (6.0 / (shape[-2] + shape[-1])) ** 0.5
        return jax.random.uniform(k, shape, jnp.float32, -lim, lim)

    h = cfg.hidden
    return HetGnnParams(
        w_embed=glorot(ks[0], (cfg.fin, h)),
        w_msg=glorot(ks[1], (EDGE_TYPES, h, h)),
        w_i=glorot(ks[2], (h, 4 * h)),
        w_h=glorot(ks[3], (h, 4 * h)),
        b=jnp.zeros((4 * h,), jnp.float32),
        w_out=glorot(ks[5], (h, cfg.horizon * cfg.fin)),
    )


def _linear(cfg: HetGnnConfig, x: jax.Array, w: jax.Array) -> jax.Array:
    if cfg.use_crossbar:
        return crossbar_linear(
            x,
            w,
            input_bits=cfg.input_bits,
            weight_bits=cfg.weight_bits,
            adc_bits=cfg.adc_bits,
            xbar_rows=cfg.xbar_rows,
        )
    return x @ w


def _lstm_step(carry, xt, *, w_i, w_h, b, hidden):
    h, c = carry
    gates = xt @ w_i + h @ w_h + b
    i, f, g, o = jnp.split(gates, 4, axis=-1)
    c = jax.nn.sigmoid(f) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
    h = jax.nn.sigmoid(o) * jnp.tanh(c)
    return (h, c), h


def hetgnn_forward(
    cfg: HetGnnConfig,
    params: HetGnnParams,
    x_hist: jax.Array,  # [B, P, Fin] own-region history
    nbr_idx: jax.Array,  # [B, EDGE_TYPES, S] neighbor rows (-1 = pad)
    nbr_table: jax.Array,  # [T, P, H] neighbor per-frame embeddings
) -> jax.Array:
    """Predict ``[B, Q, Fin]`` future demand/supply frames."""
    b, p, fin = x_hist.shape
    h = cfg.hidden

    # Per-frame node embedding (feature-extraction core).
    e = _linear(cfg, x_hist.reshape(b * p, fin), params.w_embed)
    e = jax.nn.relu(e).reshape(b, p, h)

    # Heterogeneous message passing: one aggregation per edge type
    # (aggregation core, node-stationary), type-specific transform.
    msg = jnp.zeros((b, p, h), jnp.float32)
    flat_table = nbr_table.reshape(cfg.table, p * h)
    for r in range(EDGE_TYPES):
        agg = gather_mean(flat_table, nbr_idx[:, r, :])  # [B, P*H]
        agg = agg.reshape(b * p, h)
        msg = msg + jax.nn.relu(_linear(cfg, agg, params.w_msg[r])).reshape(b, p, h)

    z = jax.nn.relu(e + msg)  # combined representation, [B, P, H]

    # LSTM over the P frames (digital recurrence).
    import functools

    step = functools.partial(
        _lstm_step, w_i=params.w_i, w_h=params.w_h, b=params.b, hidden=h
    )
    init = (jnp.zeros((b, h), jnp.float32), jnp.zeros((b, h), jnp.float32))
    (h_t, _), _ = jax.lax.scan(step, init, jnp.swapaxes(z, 0, 1))

    # Prediction head -> Q future frames.
    y = _linear(cfg, h_t, params.w_out)
    return y.reshape(b, cfg.horizon, fin)


def hetgnn_fn(cfg: HetGnnConfig):
    """Callable + example args for AOT lowering (params become inputs)."""

    def fn(x_hist, nbr_idx, nbr_table, w_embed, w_msg, w_i, w_h, b, w_out):
        params = HetGnnParams(w_embed, w_msg, w_i, w_h, b, w_out)
        return (hetgnn_forward(cfg, params, x_hist, nbr_idx, nbr_table),)

    h = cfg.hidden
    args = (
        jax.ShapeDtypeStruct((cfg.batch, cfg.hist, cfg.fin), jnp.float32),
        jax.ShapeDtypeStruct((cfg.batch, EDGE_TYPES, cfg.sample), jnp.int32),
        jax.ShapeDtypeStruct((cfg.table, cfg.hist, h), jnp.float32),
        jax.ShapeDtypeStruct((cfg.fin, h), jnp.float32),
        jax.ShapeDtypeStruct((EDGE_TYPES, h, h), jnp.float32),
        jax.ShapeDtypeStruct((h, 4 * h), jnp.float32),
        jax.ShapeDtypeStruct((h, 4 * h), jnp.float32),
        jax.ShapeDtypeStruct((4 * h,), jnp.float32),
        jax.ShapeDtypeStruct((h, cfg.horizon * cfg.fin), jnp.float32),
    )
    return fn, args
